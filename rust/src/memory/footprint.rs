//! The data-footprint model: how many bytes a network's *resident data*
//! occupies under a precision configuration (paper §3 / Table 2
//! semantics — the quantity the precision search actually optimizes).
//!
//! Footprint ≠ traffic. The traffic model ([`crate::traffic`]) counts
//! *accesses* per image; the footprint model counts *bytes resident in
//! memory* while the network runs:
//!
//! * **weights** — every layer's parameters are resident for the whole
//!   run: `Σ_l weight_elems(l) · width(wq[l])`;
//! * **peak live activations** — while layer *l* executes, its input
//!   (at the producer's format, `dq[l-1]`; the network input at
//!   `dq[0]`) and its output (at `dq[l]`) are live simultaneously; the
//!   activation footprint is the *maximum* over layers of that sum,
//!   since earlier buffers can be released once consumed.
//!
//! Widths are the **storage** widths realized by
//! [`PackedBuf`](super::PackedBuf) — `I + F` bits for packable
//! formats, 32 for fp32 and formats wider than
//! [`MAX_PACK_BITS`](super::MAX_PACK_BITS) — so inter-layer data is
//! priced at what the packed encoding actually costs, not an idealized
//! bit count.
//!
//! Scope: this is the paper's layer-granularity **data** footprint —
//! weights plus the activations crossing layer boundaries. Executor
//! *scratch* (the fast backend's im2col patch matrix and inception
//! branch temporaries, the interpreter's working vectors) is excluded
//! by design: it is backend-specific, lives in fp32 regardless of the
//! precision config (intra-group intermediates are never quantized —
//! see `PostQuant::None`), and is not part of the quantity the
//! precision search trades against accuracy. The fused packed
//! executors *do* realize the modeled bytes — activations as boundary
//! bitstreams, weights as panel/bias bitstreams — and
//! [`FootprintModel::fused_envelope`] prices the realized whole-model
//! residency (modeled weights + peak activations, plus panel padding
//! and the streaming f32 windows) so the memory tests and the CI
//! `check-mem` gate can assert the measured peak against the model.

use crate::nets::NetManifest;
use crate::search::space::PrecisionConfig;

use super::packed::storage_width;

/// Byte costs of one layer under a configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct LayerFootprint {
    pub name: String,
    /// Resident parameter bytes (weights + biases at `wq[l]`).
    pub weight_bytes: f64,
    /// Input activation bytes at the producer's data format.
    pub in_bytes: f64,
    /// Output activation bytes at `dq[l]`.
    pub out_bytes: f64,
}

impl LayerFootprint {
    /// Bytes live while this layer executes (weights are network-wide
    /// and accounted separately in [`Footprint::weight_bytes`]).
    pub fn live_act_bytes(&self) -> f64 {
        self.in_bytes + self.out_bytes
    }
}

/// Whole-network footprint under one configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Footprint {
    /// All resident parameters.
    pub weight_bytes: f64,
    /// Peak of in+out live activations over the layers.
    pub peak_act_bytes: f64,
    /// Layer index at which the activation peak occurs.
    pub peak_layer: usize,
    /// `weight_bytes + peak_act_bytes` — the paper's data footprint.
    pub total_bytes: f64,
}

/// Per-network footprint calculator, built once from a manifest. The
/// fp32 baseline total is precomputed and [`FootprintModel::footprint`]
/// allocates nothing — the greedy descent prices every candidate
/// neighbour through [`FootprintModel::ratio`], so this sits on the
/// search hot path.
///
/// # Examples
///
/// A single 16→10 fc layer: 160 weight elements plus 26 boundary
/// activations. Q1.7 weights and Q6.2 data both store 8-bit codes, so
/// the footprint is exactly a quarter of fp32; the serving/`check-mem`
/// envelope adds the f32 scratch windows on top:
///
/// ```
/// use qbound::memory::FootprintModel;
/// use qbound::quant::QFormat;
/// use qbound::search::space::PrecisionConfig;
/// # use qbound::nets::{LayerMeta, NetManifest, ParamMeta};
/// # let manifest = NetManifest {
/// #     name: "toy".into(), dataset: "synmnist".into(), num_classes: 10,
/// #     input_shape: vec![4, 4, 1], batch: 8, n_eval: 64, baseline_top1: 0.9,
/// #     layers: vec![LayerMeta { name: "fc".into(), kind: "fc".into(), in_elems: 16,
/// #         out_elems: 10, weight_elems: 160, macs: 160, stages: vec!["fc".into()] }],
/// #     params: vec![ParamMeta { name: "w".into(), shape: vec![160] }],
/// #     hlo_file: "x".into(), weights_file: "x".into(), dataset_file: "x".into(),
/// #     stage_variant: None, dir: std::path::PathBuf::from("/tmp"),
/// # };
/// let fpm = FootprintModel::new(&manifest);
/// assert_eq!(fpm.fp32().total_bytes, (160.0 + 26.0) * 4.0);
///
/// let cfg = PrecisionConfig::uniform(1, QFormat::new(1, 7), QFormat::new(6, 2));
/// assert_eq!(fpm.footprint(&cfg).total_bytes, 160.0 + 26.0);
/// assert_eq!(fpm.reduction(&cfg), 0.75);
///
/// // 26 f32 window elements and no panel padding: the realized bound.
/// assert_eq!(fpm.fused_envelope(&cfg, 26, &[0]), 186.0 + 4.0 * 26.0);
/// ```
#[derive(Clone, Debug)]
pub struct FootprintModel {
    layers: Vec<(String, u64, u64, u64)>, // (name, in, out, weights)
    fp32_total: f64,
}

impl FootprintModel {
    pub fn new(m: &NetManifest) -> FootprintModel {
        let layers: Vec<(String, u64, u64, u64)> = m
            .layers
            .iter()
            .map(|l| (l.name.clone(), l.in_elems, l.out_elems, l.weight_elems))
            .collect();
        // fp32 baseline: everything 4 bytes/elem.
        let weight_bytes: f64 = layers.iter().map(|(_, _, _, w)| *w as f64 * 4.0).sum();
        let peak_act = layers
            .iter()
            .map(|(_, i, o, _)| (i + o) as f64 * 4.0)
            .fold(0f64, f64::max);
        FootprintModel { layers, fp32_total: weight_bytes + peak_act }
    }

    pub fn n_layers(&self) -> usize {
        self.layers.len()
    }

    /// Per-layer byte breakdown under `cfg` (display paths).
    pub fn per_layer(&self, cfg: &PrecisionConfig) -> Vec<LayerFootprint> {
        assert_eq!(cfg.n_layers(), self.layers.len(), "config/model layer mismatch");
        let bytes = |elems: u64, width: u32| elems as f64 * width as f64 / 8.0;
        self.layers
            .iter()
            .enumerate()
            .map(|(l, (name, in_e, out_e, w_e))| {
                let in_fmt = if l == 0 { cfg.dq[0] } else { cfg.dq[l - 1] };
                LayerFootprint {
                    name: name.clone(),
                    weight_bytes: bytes(*w_e, storage_width(cfg.wq[l])),
                    in_bytes: bytes(*in_e, storage_width(in_fmt)),
                    out_bytes: bytes(*out_e, storage_width(cfg.dq[l])),
                }
            })
            .collect()
    }

    /// Aggregate footprint under `cfg`: total weights + peak live
    /// activations. Allocation-free.
    pub fn footprint(&self, cfg: &PrecisionConfig) -> Footprint {
        assert_eq!(cfg.n_layers(), self.layers.len(), "config/model layer mismatch");
        let bytes = |elems: u64, width: u32| elems as f64 * width as f64 / 8.0;
        let mut weight_bytes = 0f64;
        let (mut peak_layer, mut peak_act_bytes) = (0usize, 0f64);
        for (l, (_, in_e, out_e, w_e)) in self.layers.iter().enumerate() {
            weight_bytes += bytes(*w_e, storage_width(cfg.wq[l]));
            let in_fmt = if l == 0 { cfg.dq[0] } else { cfg.dq[l - 1] };
            let live =
                bytes(*in_e, storage_width(in_fmt)) + bytes(*out_e, storage_width(cfg.dq[l]));
            if live > peak_act_bytes {
                peak_act_bytes = live;
                peak_layer = l;
            }
        }
        Footprint {
            weight_bytes,
            peak_act_bytes,
            peak_layer,
            total_bytes: weight_bytes + peak_act_bytes,
        }
    }

    /// The all-fp32 baseline footprint.
    pub fn fp32(&self) -> Footprint {
        self.footprint(&PrecisionConfig::fp32(self.layers.len()))
    }

    /// Footprint ratio vs the fp32 baseline (the search's ranking key;
    /// `1 - ratio` is the paper's "% reduction").
    pub fn ratio(&self, cfg: &PrecisionConfig) -> f64 {
        self.footprint(cfg).total_bytes / self.fp32_total
    }

    /// Footprint reduction vs fp32 as a fraction in [0, 1).
    pub fn reduction(&self, cfg: &PrecisionConfig) -> f64 {
        1.0 - self.ratio(cfg)
    }

    /// The *realized* whole-model residency bound of the fused packed
    /// executors. [`FootprintModel::footprint`] already prices both the
    /// weights and the peak live activations at the storage widths
    /// packed buffers realize ([`Footprint::total_bytes`]); on top of
    /// that the runtime keeps
    ///
    /// * the NR-lane zero padding the GEMM panel layout adds to each
    ///   group's weight bitstream (`weight_pad_elems`, the lowered
    ///   plan's `weight_pad_elems`, priced at the group's weight
    ///   width), and
    /// * the streaming f32 scratch windows (`window_f32_elems` — the
    ///   lowered plan's `fused_window_elems(1)` budget: the
    ///   `max_win_elems` decode window, the `max_bias_elems` bias
    ///   window, and the `strip_cache_elems` decoded-weight-strip
    ///   cache).
    ///
    /// `tests/integration_memory.rs` asserts the measured resident
    /// delta of a packed run lands inside this envelope, and the CI
    /// `check-mem` gate holds each archived `MEM_*.json` peak against
    /// it — the step that turns FOOTPRINT.json from a model into a
    /// measurement, for weights *and* activations.
    pub fn fused_envelope(
        &self,
        cfg: &PrecisionConfig,
        window_f32_elems: usize,
        weight_pad_elems: &[usize],
    ) -> f64 {
        assert_eq!(weight_pad_elems.len(), self.layers.len(), "padding/model layer mismatch");
        let pad: f64 = weight_pad_elems
            .iter()
            .zip(&cfg.wq)
            .map(|(&e, q)| e as f64 * storage_width(*q) as f64 / 8.0)
            .sum();
        self.footprint(cfg).total_bytes + pad + 4.0 * window_f32_elems as f64
    }

    /// The weight component of [`FootprintModel::fused_envelope`]: all
    /// resident parameter bytes at `cfg.wq` storage widths *plus* the
    /// GEMM panel padding at the same widths. This is exactly the slice
    /// of an executor's residency that the packed-weight store
    /// ([`crate::store`]) can share between executors whose weight
    /// formats agree — the serving cache prices it once per distinct
    /// (network, `wq`) pair when store-backed sharing is active.
    pub fn shared_weight_bytes(&self, cfg: &PrecisionConfig, weight_pad_elems: &[usize]) -> f64 {
        assert_eq!(weight_pad_elems.len(), self.layers.len(), "padding/model layer mismatch");
        let pad: f64 = weight_pad_elems
            .iter()
            .zip(&cfg.wq)
            .map(|(&e, q)| e as f64 * storage_width(*q) as f64 / 8.0)
            .sum();
        self.footprint(cfg).weight_bytes + pad
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nets::{LayerMeta, ParamMeta};
    use crate::quant::QFormat;
    use std::path::PathBuf;

    fn toy_manifest() -> NetManifest {
        NetManifest {
            name: "toy".into(),
            dataset: "synmnist".into(),
            num_classes: 10,
            input_shape: vec![4, 4, 1],
            batch: 8,
            n_eval: 64,
            baseline_top1: 0.9,
            layers: vec![
                LayerMeta {
                    name: "L1".into(),
                    kind: "conv".into(),
                    in_elems: 16,
                    out_elems: 8,
                    weight_elems: 20,
                    macs: 100,
                    stages: vec!["conv".into()],
                },
                LayerMeta {
                    name: "L2".into(),
                    kind: "fc".into(),
                    in_elems: 8,
                    out_elems: 10,
                    weight_elems: 90,
                    macs: 80,
                    stages: vec!["fc".into()],
                },
            ],
            params: vec![
                ParamMeta { name: "w1".into(), shape: vec![20] },
                ParamMeta { name: "w2".into(), shape: vec![90] },
            ],
            hlo_file: "x".into(),
            weights_file: "x".into(),
            dataset_file: "x".into(),
            stage_variant: None,
            dir: PathBuf::from("/tmp"),
        }
    }

    #[test]
    fn fp32_baseline_by_hand() {
        let fpm = FootprintModel::new(&toy_manifest());
        let base = fpm.fp32();
        // weights: (20 + 90) * 4 bytes
        assert_eq!(base.weight_bytes, 110.0 * 4.0);
        // live activations: L1 has (16+8)*4 = 96, L2 has (8+10)*4 = 72
        assert_eq!(base.peak_act_bytes, 96.0);
        assert_eq!(base.peak_layer, 0);
        assert_eq!(base.total_bytes, 440.0 + 96.0);
    }

    #[test]
    fn quantized_bytes_by_hand() {
        let fpm = FootprintModel::new(&toy_manifest());
        // w 1.7 (8 bits), d 6.2 (8 bits) everywhere => exactly 1/4 of fp32.
        let cfg = PrecisionConfig::uniform(2, QFormat::new(1, 7), QFormat::new(6, 2));
        let fp = fpm.footprint(&cfg);
        assert_eq!(fp.weight_bytes, 110.0);
        assert_eq!(fp.peak_act_bytes, 24.0);
        assert!((fpm.ratio(&cfg) - 0.25).abs() < 1e-12);
        assert!((fpm.reduction(&cfg) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn input_priced_at_producer_format() {
        let fpm = FootprintModel::new(&toy_manifest());
        let mut cfg = PrecisionConfig::fp32(2);
        cfg.dq[0] = QFormat::new(14, 2); // 16 bits
        cfg.dq[1] = QFormat::new(6, 2); // 8 bits
        let per = fpm.per_layer(&cfg);
        assert_eq!(per[0].in_bytes, 16.0 * 2.0); // input at dq[0]
        assert_eq!(per[0].out_bytes, 8.0 * 2.0); // L1 out at dq[0]
        assert_eq!(per[1].in_bytes, 8.0 * 2.0); // L2 in at dq[0] (producer)
        assert_eq!(per[1].out_bytes, 10.0 * 1.0); // L2 out at dq[1]
    }

    #[test]
    fn wide_formats_cost_32_bits() {
        let fpm = FootprintModel::new(&toy_manifest());
        // 26-bit data format has no packed encoding: priced as 32-bit.
        let cfg = PrecisionConfig::uniform(2, QFormat::new(1, 7), QFormat::new(14, 12));
        let per = fpm.per_layer(&cfg);
        assert_eq!(per[0].in_bytes, 16.0 * 4.0);
    }

    #[test]
    fn cached_baseline_matches_recomputation() {
        let fpm = FootprintModel::new(&toy_manifest());
        let base = fpm.fp32();
        // ratio() divides by the precomputed fp32 total; the two must agree.
        assert!((fpm.ratio(&PrecisionConfig::fp32(2)) - 1.0).abs() < 1e-12);
        assert_eq!(base.total_bytes, 440.0 + 96.0);
        // footprint() aggregates must agree with the per_layer breakdown.
        let cfg = PrecisionConfig::uniform(2, QFormat::new(1, 7), QFormat::new(6, 2));
        let per = fpm.per_layer(&cfg);
        let fp = fpm.footprint(&cfg);
        assert_eq!(fp.weight_bytes, per.iter().map(|l| l.weight_bytes).sum::<f64>());
        let peak = per.iter().map(|l| l.live_act_bytes()).fold(0f64, f64::max);
        assert_eq!(fp.peak_act_bytes, peak);
    }

    #[test]
    fn fused_envelope_prices_whole_model_residency() {
        let fpm = FootprintModel::new(&toy_manifest());
        let cfg = PrecisionConfig::uniform(2, QFormat::new(1, 7), QFormat::new(6, 2));
        let fp = fpm.footprint(&cfg);
        // No scratch, no padding: exactly the modeled weights + peak acts.
        assert_eq!(fpm.fused_envelope(&cfg, 0, &[0, 0]), fp.total_bytes);
        // 100 f32 window elems cost 400 bytes; 24 padding elems at the
        // 8-bit weight width cost 24 bytes.
        assert_eq!(fpm.fused_envelope(&cfg, 100, &[16, 8]), fp.total_bytes + 400.0 + 24.0);
        // fp32 configs still bound: everything priced at 32 bits,
        // padding included.
        let base = fpm.fp32();
        let fp32 = PrecisionConfig::fp32(2);
        assert_eq!(fpm.fused_envelope(&fp32, 0, &[0, 0]), base.total_bytes);
        assert_eq!(fpm.fused_envelope(&fp32, 0, &[2, 0]), base.total_bytes + 8.0);
    }

    #[test]
    fn shared_weight_bytes_is_the_envelope_weight_component() {
        let fpm = FootprintModel::new(&toy_manifest());
        let cfg = PrecisionConfig::uniform(2, QFormat::new(1, 7), QFormat::new(6, 2));
        // 110 weight elems at 8 bits + (16+8) padding elems at 8 bits.
        assert_eq!(fpm.shared_weight_bytes(&cfg, &[16, 8]), 110.0 + 24.0);
        // Envelope = shared weights + peak acts + f32 windows.
        let fp = fpm.footprint(&cfg);
        assert_eq!(
            fpm.fused_envelope(&cfg, 100, &[16, 8]),
            fpm.shared_weight_bytes(&cfg, &[16, 8]) + fp.peak_act_bytes + 400.0
        );
    }

    #[test]
    fn monotone_in_bits() {
        let fpm = FootprintModel::new(&toy_manifest());
        let narrow = PrecisionConfig::uniform(2, QFormat::new(1, 3), QFormat::new(4, 0));
        let wide = PrecisionConfig::uniform(2, QFormat::new(1, 11), QFormat::new(10, 2));
        assert!(fpm.footprint(&narrow).total_bytes < fpm.footprint(&wide).total_bytes);
        assert!((fpm.ratio(&PrecisionConfig::fp32(2)) - 1.0).abs() < 1e-12);
    }
}
