//! `qbound frontier` — export per-net accuracy↔footprint rung ladders
//! (`FRONTIER_<net>.json`) for `qbound serve --autoscale`.
//!
//! Reuses the paper's §2.5 machinery end to end: the greedy descent
//! (`qbound search` / Fig 5) supplies measured `(config, accuracy,
//! footprint ratio)` points, [`pareto::frontier`] keeps the
//! non-dominated ones, and [`FootprintModel::fused_envelope`] prices
//! each surviving rung in the serve daemon's admission currency. The
//! ladder is ordered widest (rung 0) to narrowest; the daemon clamps
//! it at `--accuracy-floor` load time, so this command exports the
//! whole frontier and prints how much of it a given floor keeps.
//!
//! When `BENCH_*.json` files from `qbound bench` sit next to the
//! output, the net's best measured packed/f32 kernel time ratio is
//! attached as `packed_over_f32_time` — the throughput side of the
//! ladder, for operators reading the file.

use anyhow::Result;
use qbound::backend::lowering::LoweredPlan;
use qbound::backend::BackendKind;
use qbound::cli::CmdSpec;
use qbound::memory::FootprintModel;
use qbound::nets::{arch, ArtifactIndex};
use qbound::report::{pct, ratio, Table};
use qbound::repro::{self, ReproCtx};
use qbound::search::pareto;
use qbound::serve::frontier::{Frontier, Rung};
use qbound::util::{self, json::Json};

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new(
        "frontier",
        "export per-net accuracy-footprint rung ladders for serve --autoscale",
    )
    .opt("net", "network name, or 'all'", "all")
    .opt("n-images", "images per evaluation (0 = full)", "128")
    .opt("workers", "worker threads (0 = one per core)", "0")
    .opt(
        "backend",
        "execution backend: reference | fast | pjrt (default: env or reference)",
        "",
    )
    .opt("out-dir", "directory for FRONTIER_<net>.json (BENCH_*.json read from here too)", "bench-out")
    .opt(
        "cache-dir",
        "descent-trajectory cache directory; \"none\" disables caching",
        "reports/dse-cache",
    )
    .opt("max-rungs", "cap on ladder length (endpoints kept, middle thinned evenly)", "6")
    .opt("floor", "accuracy floor for the printed usable-rung summary", "0.01");
    let a = spec.parse(args)?;

    let max_rungs = a.usize("max-rungs")?;
    anyhow::ensure!(max_rungs >= 2, "--max-rungs must be >= 2 (a ladder needs two ends)");
    let floor = a.f64("floor")?;
    let mut ctx = ReproCtx::with_backend(
        std::path::Path::new(a.str("out-dir")),
        a.usize("workers")?,
        a.usize("n-images")?,
        BackendKind::from_arg_or_env(a.str("backend"))?,
    )?;
    let nets: Vec<String> = if a.str("net") == "all" {
        ArtifactIndex::load(&ctx.artifacts)?.nets
    } else {
        vec![a.str("net").to_string()]
    };
    let out_dir = std::path::PathBuf::from(a.str("out-dir"));
    let cache_dir = a.str("cache-dir").to_string();

    let mut t = Table::new(
        "Autoscale frontiers — rung ladders (rung 0 widest)",
        &["net", "rung", "config", "top-1", "rel err", "FP ratio", "envelope"],
    );
    for net in &nets {
        let m = ctx.manifest(net)?.clone();
        let Some(net_arch) = arch::get(net) else {
            println!("{net}: no registered architecture, skipping");
            continue;
        };
        let fpm = FootprintModel::new(&m);
        let plan = LoweredPlan::new(&net_arch, None)?;
        let window = plan.fused_window_elems(1);
        let pads = plan.weight_pad_elems.clone();

        // The descent dominates the cost; the cache key (net, backend,
        // n-images, weights hash) is shared with `qbound footprint`, so
        // CI pays for the trajectory once.
        let dse = if cache_dir == "none" {
            repro::explore_net(&mut ctx, net)?
        } else {
            repro::explore_net_cached(&mut ctx, net, std::path::Path::new(&cache_dir))?
        };
        let mut points = dse.descent.visited.clone();
        points.extend(dse.descent.explored.iter().cloned());
        anyhow::ensure!(!points.is_empty(), "{net}: descent visited no configurations");

        // Non-dominated in (footprint ↓, accuracy ↑); pareto returns
        // cost-ascending, the ladder wants widest (highest-cost) first.
        let xy: Vec<(f64, f64)> = points.iter().map(|v| (v.footprint_ratio, v.accuracy)).collect();
        let mut keep = pareto::frontier(&xy);
        keep.reverse();
        let keep = thin(keep, max_rungs);

        let rungs: Vec<Rung> = keep
            .iter()
            .map(|&i| {
                let v = &points[i];
                Rung {
                    cfg: v.cfg.clone(),
                    accuracy: v.accuracy,
                    // The descent's rel_err is signed (a config can beat
                    // the sampled baseline); the ladder's floor semantics
                    // only care about loss.
                    rel_err: v.rel_err.max(0.0),
                    footprint_ratio: v.footprint_ratio,
                    envelope_bytes: fpm.fused_envelope(&v.cfg, window, &pads),
                }
            })
            .collect();
        let f = Frontier {
            net: net.clone(),
            baseline_accuracy: dse.descent.baseline,
            rungs,
        };
        f.validate()?;

        for (i, r) in f.rungs.iter().enumerate() {
            t.row(vec![
                if i == 0 { net.clone() } else { String::new() },
                i.to_string(),
                r.cfg.notation(),
                pct(r.accuracy),
                format!("{:.4}", r.rel_err),
                ratio(r.footprint_ratio),
                util::human_bytes(r.envelope_bytes),
            ]);
        }

        // Attach the bench throughput hint when bench artifacts exist
        // next to the output (extra key — the serve loader ignores it).
        let mut doc = f.to_json();
        if let (Json::Obj(map), Some(r)) = (&mut doc, bench_time_ratio(&out_dir, net)) {
            map.insert("packed_over_f32_time".to_string(), Json::num(r));
        }
        let path = out_dir.join(Frontier::file_name(net));
        util::write_file(&path, doc.pretty().as_bytes())?;
        println!(
            "{net}: {} rung(s) ({} usable at floor {floor}) -> {}",
            f.rungs.len(),
            f.usable_rungs(floor),
            path.display()
        );
    }
    print!("{}", t.text());
    Ok(())
}

/// Evenly thin an index ladder to at most `max` entries, always keeping
/// both endpoints (the widest and narrowest rungs).
fn thin(keep: Vec<usize>, max: usize) -> Vec<usize> {
    if keep.len() <= max {
        return keep;
    }
    (0..max).map(|i| keep[i * (keep.len() - 1) / (max - 1)]).collect()
}

/// The net's best (smallest) measured packed/f32 kernel time ratio from
/// any `BENCH_*.json` in `dir`, if one is there.
fn bench_time_ratio(dir: &std::path::Path, net: &str) -> Option<f64> {
    let mut best: Option<f64> = None;
    for entry in std::fs::read_dir(dir).ok()? {
        let Ok(entry) = entry else { continue };
        let path = entry.path();
        let Some(name) = path.file_name().and_then(|n| n.to_str()) else { continue };
        if !name.starts_with("BENCH_") || !name.ends_with(".json") {
            continue;
        }
        let Ok(text) = std::fs::read_to_string(&path) else { continue };
        let Ok(doc) = Json::parse(&text) else { continue };
        let Some(rows) = doc.get("ratios").and_then(Json::as_arr) else { continue };
        for row in rows {
            if row.get("net").and_then(Json::as_str) == Some(net) {
                if let Some(r) = row.get("packed_over_f32").and_then(Json::as_f64) {
                    best = Some(best.map_or(r, |b: f64| b.min(r)));
                }
            }
        }
    }
    best
}
