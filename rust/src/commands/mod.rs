//! CLI subcommand implementations (thin veneers over the `qbound` library).

pub mod check_mem;
pub mod eval;
pub mod footprint_cmd;
pub mod frontier_cmd;
pub mod gen_artifacts;
pub mod info;
pub mod profile;
pub mod repro_cmd;
pub mod search_cmd;
pub mod serve;
pub mod store_cmd;
pub mod sweeps;
pub mod traffic_cmd;
