//! `qbound eval` — accuracy of one precision configuration.

use anyhow::Result;
use qbound::backend::BackendKind;
use qbound::cli::CmdSpec;
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::memory::{FootprintModel, StorageMode};
use qbound::nets::NetManifest;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::traffic::{self, Mode};
use qbound::util;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("eval", "evaluate a precision configuration")
        .opt("net", "network name", "lenet")
        .opt("weights", "uniform weight format I.F (or fp32)", "fp32")
        .opt("data", "uniform data format I.F (or fp32)", "fp32")
        .opt(
            "data-per-layer",
            "comma-separated per-layer data formats, overrides --data",
            "",
        )
        .opt(
            "weights-per-layer",
            "comma-separated per-layer weight formats, overrides --weights",
            "",
        )
        .opt("n-images", "images to evaluate (0 = full split)", "0")
        .opt("workers", "worker threads (0 = one per core)", "0")
        .opt("batch", "images per infer call (0 = largest the backend allows)", "0")
        .opt(
            "backend",
            "execution backend: reference | fast | pjrt (default: env or reference)",
            "",
        )
        .opt(
            "storage",
            "inter-layer activation storage: f32 | packed (default: env or f32)",
            "",
        )
        .opt(
            "mem-json",
            "write measured peak RSS + modeled footprint JSON to this path",
            "",
        )
        .opt(
            "trace",
            "write a Chrome trace_event JSON of the evaluation to this path",
            "",
        );
    let a = spec.parse(args)?;
    if !a.str("trace").is_empty() {
        qbound::obs::set_tracing(true);
    }

    let dir = util::artifacts_dir()?;
    let net = a.str("net").to_string();
    let m = NetManifest::load(&dir, &net)?;
    let nl = m.n_layers();

    let mut cfg = PrecisionConfig::uniform(
        nl,
        QFormat::parse(a.str("weights"))?,
        QFormat::parse(a.str("data"))?,
    );
    let per_layer = |list: &str| -> Result<Vec<QFormat>> {
        let v: Vec<QFormat> =
            list.split(',').map(QFormat::parse).collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(v.len() == nl, "need {nl} formats, got {}", v.len());
        Ok(v)
    };
    if !a.str("data-per-layer").is_empty() {
        cfg.dq = per_layer(a.str("data-per-layer"))?;
    }
    if !a.str("weights-per-layer").is_empty() {
        cfg.wq = per_layer(a.str("weights-per-layer"))?;
    }

    let backend = BackendKind::from_arg_or_env(a.str("backend"))?;
    // Coordinator workers construct their backends from the environment,
    // so an explicit --storage is propagated through QBOUND_STORAGE.
    let storage = StorageMode::from_arg_or_env(a.str("storage"))?;
    storage.set_env();
    let mut coord = Coordinator::with_backend(&dir, a.usize("workers")?, backend)?;
    coord.set_eval_batch(a.usize("batch")?);
    let n_images = a.usize("n-images")?;
    let base = coord.eval_one(EvalJob {
        net: net.clone(),
        cfg: PrecisionConfig::fp32(nl),
        n_images,
    })?;
    // For --mem-json, scope the peak-RSS watermark to the *target*
    // config's evaluation — the fp32 baseline above would otherwise set
    // a process-lifetime high-water that masks any packed-mode
    // regression.
    let rss_scoped = !a.str("mem-json").is_empty() && util::reset_peak_rss();
    let acc = coord.eval_one(EvalJob { net: net.clone(), cfg: cfg.clone(), n_images })?;
    let tr = traffic::traffic_ratio(&m, Mode::Batch(m.batch), &cfg);
    let fpm = FootprintModel::new(&m);
    let (fp_base, fp) = (fpm.fp32(), fpm.footprint(&cfg));
    // The PJRT backend executes on-device and ignores QBOUND_STORAGE;
    // don't claim a storage mode that never ran.
    let storage_label = match backend {
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => "f32 (pjrt backend ignores --storage)",
        _ => storage.label(),
    };
    println!("net:            {net}");
    println!("config:         {cfg}");
    println!("storage:        {storage_label}");
    println!("top-1:          {acc:.4}  (baseline {base:.4})");
    println!("relative error: {:.4}", (base - acc) / base.max(1e-9));
    println!("traffic ratio:  {tr:.3} vs fp32  ({:.0}% reduction)", (1.0 - tr) * 100.0);
    println!(
        "footprint:      {} vs {} fp32  ({:.0}% reduction; weights {}, peak acts {})",
        util::human_bytes(fp.total_bytes),
        util::human_bytes(fp_base.total_bytes),
        fpm.reduction(&cfg) * 100.0,
        util::human_bytes(fp.weight_bytes),
        util::human_bytes(fp.peak_act_bytes),
    );
    let peak_rss = util::peak_rss_bytes();
    if let Some(rss) = peak_rss {
        println!("peak rss:       {} (process VmHWM)", util::human_bytes(rss as f64));
    }
    // Measured-vs-modeled memory record for CI archiving: regressions
    // in the realized bound show up next to FOOTPRINT.json per commit,
    // and `qbound check-mem` fails the build when the measured peak
    // escapes the modeled envelope.
    if !a.str("mem-json").is_empty() {
        use qbound::backend::lowering::LoweredPlan;
        use qbound::nets::arch;
        use qbound::util::json::Json;
        let arch = arch::get(&net)
            .ok_or_else(|| anyhow::anyhow!("no architecture registered for {net:?}"))?;
        let plan = LoweredPlan::new(&arch, None)?;
        // Whole-model residency bound of the fused packed executor:
        // modeled weights + peak acts + panel padding + f32 windows.
        let envelope =
            fpm.fused_envelope(&cfg, plan.fused_window_elems(1), &plan.weight_pad_elems);
        // Priced from the plan alone — identical to packing the real
        // tensors (the tests pin the equality), without re-reading the
        // weights file.
        let weight_bytes = plan.packed_weight_bytes(&cfg.wq);
        let doc = Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("net", Json::str(net.clone())),
            ("backend", Json::str(backend.label())),
            ("storage", Json::str(storage_label)),
            ("config", Json::str(cfg.notation())),
            ("n_images", Json::num(n_images as f64)),
            (
                "peak_rss_bytes",
                peak_rss.map(|b| Json::num(b as f64)).unwrap_or(Json::Null),
            ),
            // "target-eval": watermark reset before the measured config
            // ran; "process": lifetime high-water incl. the baseline.
            (
                "peak_rss_scope",
                Json::str(if rss_scoped { "target-eval" } else { "process" }),
            ),
            ("modeled_fp32_bytes", Json::num(fp_base.total_bytes)),
            ("modeled_bytes", Json::num(fp.total_bytes)),
            // The check-mem gate compares the measured peak against
            // this envelope (plus a process-overhead slack).
            ("fused_envelope_bytes", Json::num(envelope)),
            ("packed_weight_bytes", Json::num(weight_bytes as f64)),
            ("top1", Json::num(acc)),
        ]);
        let path = std::path::PathBuf::from(a.str("mem-json"));
        util::write_file(&path, doc.pretty().as_bytes())?;
        eprintln!("memory json -> {}", path.display());
    }
    if !a.str("trace").is_empty() {
        qbound::obs::set_tracing(false);
        let path = std::path::PathBuf::from(a.str("trace"));
        qbound::obs::write_chrome_trace(&path, &qbound::obs::drain())?;
        eprintln!("trace -> {}", path.display());
    }
    Ok(())
}
