//! `qbound eval` — accuracy of one precision configuration.

use anyhow::Result;
use qbound::backend::BackendKind;
use qbound::cli::CmdSpec;
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::memory::{FootprintModel, StorageMode};
use qbound::nets::NetManifest;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::traffic::{self, Mode};
use qbound::util;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("eval", "evaluate a precision configuration")
        .opt("net", "network name", "lenet")
        .opt("weights", "uniform weight format I.F (or fp32)", "fp32")
        .opt("data", "uniform data format I.F (or fp32)", "fp32")
        .opt(
            "data-per-layer",
            "comma-separated per-layer data formats, overrides --data",
            "",
        )
        .opt(
            "weights-per-layer",
            "comma-separated per-layer weight formats, overrides --weights",
            "",
        )
        .opt("n-images", "images to evaluate (0 = full split)", "0")
        .opt("workers", "worker threads (0 = one per core)", "0")
        .opt("batch", "images per infer call (0 = largest the backend allows)", "0")
        .opt("backend", "execution backend: reference | fast | pjrt (default: env or reference)", "")
        .opt(
            "storage",
            "inter-layer activation storage: f32 | packed (default: env or f32)",
            "",
        );
    let a = spec.parse(args)?;

    let dir = util::artifacts_dir()?;
    let net = a.str("net").to_string();
    let m = NetManifest::load(&dir, &net)?;
    let nl = m.n_layers();

    let mut cfg = PrecisionConfig::uniform(
        nl,
        QFormat::parse(a.str("weights"))?,
        QFormat::parse(a.str("data"))?,
    );
    let per_layer = |list: &str| -> Result<Vec<QFormat>> {
        let v: Vec<QFormat> =
            list.split(',').map(QFormat::parse).collect::<Result<Vec<_>>>()?;
        anyhow::ensure!(v.len() == nl, "need {nl} formats, got {}", v.len());
        Ok(v)
    };
    if !a.str("data-per-layer").is_empty() {
        cfg.dq = per_layer(a.str("data-per-layer"))?;
    }
    if !a.str("weights-per-layer").is_empty() {
        cfg.wq = per_layer(a.str("weights-per-layer"))?;
    }

    let backend = BackendKind::from_arg_or_env(a.str("backend"))?;
    // Coordinator workers construct their backends from the environment,
    // so an explicit --storage is propagated through QBOUND_STORAGE.
    let storage = StorageMode::from_arg_or_env(a.str("storage"))?;
    storage.set_env();
    let mut coord = Coordinator::with_backend(&dir, a.usize("workers")?, backend)?;
    coord.set_eval_batch(a.usize("batch")?);
    let n_images = a.usize("n-images")?;
    let base = coord.eval_one(EvalJob {
        net: net.clone(),
        cfg: PrecisionConfig::fp32(nl),
        n_images,
    })?;
    let acc = coord.eval_one(EvalJob { net: net.clone(), cfg: cfg.clone(), n_images })?;
    let tr = traffic::traffic_ratio(&m, Mode::Batch(m.batch), &cfg);
    let fpm = FootprintModel::new(&m);
    let (fp_base, fp) = (fpm.fp32(), fpm.footprint(&cfg));
    // The PJRT backend executes on-device and ignores QBOUND_STORAGE;
    // don't claim a storage mode that never ran.
    let storage_label = match backend {
        #[cfg(feature = "pjrt")]
        BackendKind::Pjrt => "f32 (pjrt backend ignores --storage)",
        _ => storage.label(),
    };
    println!("net:            {net}");
    println!("config:         {cfg}");
    println!("storage:        {storage_label}");
    println!("top-1:          {acc:.4}  (baseline {base:.4})");
    println!("relative error: {:.4}", (base - acc) / base.max(1e-9));
    println!("traffic ratio:  {tr:.3} vs fp32  ({:.0}% reduction)", (1.0 - tr) * 100.0);
    println!(
        "footprint:      {} vs {} fp32  ({:.0}% reduction; weights {}, peak acts {})",
        util::human_bytes(fp.total_bytes),
        util::human_bytes(fp_base.total_bytes),
        fpm.reduction(&cfg) * 100.0,
        util::human_bytes(fp.weight_bytes),
        util::human_bytes(fp.peak_act_bytes),
    );
    Ok(())
}
