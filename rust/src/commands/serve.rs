//! `qbound serve` — replay a Poisson classification request stream against
//! a quantized network: the "bounded-memory deployment" E2E driver.

use std::time::Duration;

use anyhow::Result;
use qbound::backend::BackendKind;
use qbound::cli::CmdSpec;
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::nets::NetManifest;
use qbound::prng::Xoshiro256pp;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::traffic::{self, Mode};
use qbound::util;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("serve", "serve a timed classification request stream")
        .opt("net", "network name", "lenet")
        .opt("requests", "number of requests", "64")
        .opt("rate", "mean arrival rate (requests/s)", "8")
        .opt("weights", "weight format I.F (or fp32)", "1.8")
        .opt("data", "data format I.F (or fp32)", "10.2")
        .opt("batches-per-request", "eval batches per request", "1")
        .opt("workers", "worker threads (0 = one per core)", "0")
        .opt("seed", "arrival-process seed", "42")
        .opt(
            "backend",
            "execution backend: reference | fast | pjrt (default: env or reference)",
            "",
        );
    let a = spec.parse(args)?;
    let dir = util::artifacts_dir()?;
    let net = a.str("net").to_string();
    let m = NetManifest::load(&dir, &net)?;
    let cfg = PrecisionConfig::uniform(
        m.n_layers(),
        QFormat::parse(a.str("weights"))?,
        QFormat::parse(a.str("data"))?,
    );
    let n_req = a.usize("requests")?;
    let rate = a.f64("rate")?;
    let n_images = a.usize("batches-per-request")? * m.batch;

    let backend = BackendKind::from_arg_or_env(a.str("backend"))?;
    let mut coord = Coordinator::with_backend(&dir, a.usize("workers")?, backend)?;
    // Warm the executors (load once, off the clock) with the fp32 config.
    coord.eval_one(EvalJob {
        net: net.clone(),
        cfg: PrecisionConfig::fp32(m.n_layers()),
        n_images,
    })?;

    let mut rng = Xoshiro256pp::new(a.usize("seed")? as u64);
    let mut arrivals = Vec::with_capacity(n_req);
    let mut t = 0.0f64;
    let nl = m.n_layers();
    for i in 0..n_req {
        t += rng.exponential(rate);
        // per-request UNIQUE config (two rotating per-layer fields span a
        // space ≫ n_req) so the memo cache cannot shortcut service —
        // every request pays real inference.
        let mut c = cfg.clone();
        c.dq[i % nl].fbits = 2 + ((i / nl) % 12) as i8;
        c.dq[(i + 1) % nl].ibits = 8 + ((i / (nl * 12)) % 6) as i8;
        arrivals.push((Duration::from_secs_f64(t), EvalJob {
            net: net.clone(),
            cfg: c,
            n_images,
        }));
    }

    let t0 = std::time::Instant::now();
    let lat = coord.run_stream(&arrivals)?;
    let wall = t0.elapsed();

    let mut sorted = lat.clone();
    sorted.sort_unstable();
    let p = |q: f64| sorted[((sorted.len() - 1) as f64 * q) as usize];
    let tr = traffic::traffic_ratio(&m, Mode::Batch(m.batch), &cfg);
    println!(
        "serve — {net} @ {} req, {} imgs/req, rate {rate}/s, {} workers",
        n_req, n_images, coord.n_workers
    );
    println!("  config            {cfg}");
    println!("  traffic ratio     {tr:.3} vs fp32 ({:.0}% reduction)", (1.0 - tr) * 100.0);
    println!("  wall time         {}", util::human_duration(wall));
    println!(
        "  throughput        {:.1} req/s   {:.0} images/s",
        n_req as f64 / wall.as_secs_f64(),
        (n_req * n_images) as f64 / wall.as_secs_f64()
    );
    println!(
        "  latency           p50 {}  p95 {}  p99 {}  max {}",
        util::human_duration(p(0.50)),
        util::human_duration(p(0.95)),
        util::human_duration(p(0.99)),
        util::human_duration(*sorted.last().unwrap())
    );
    let busy = coord.busy_time().as_secs_f64();
    println!(
        "  worker utilization {:.0}%  (busy {:.2}s over {} workers)",
        100.0 * busy / (wall.as_secs_f64() * coord.n_workers as f64),
        busy,
        coord.n_workers
    );
    Ok(())
}
