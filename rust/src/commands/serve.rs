//! `qbound serve` — the footprint-budgeted HTTP inference daemon, plus
//! the self-driving `--smoke` mode CI runs against a live TCP endpoint.
//!
//! Daemon mode binds `--addr` and serves `POST /v1/classify` until
//! killed; executors are admitted against `--mem-budget-mb` (see
//! [`qbound::serve`] and docs/OPERATIONS.md). Smoke mode starts the same
//! server on an ephemeral port, replays a fixed mixed two-net workload
//! over real sockets, checks every prediction against a freshly loaded
//! reference-backend oracle, probes the protocol error paths, asserts
//! the latency SLO and the RSS budget, archives `SERVE_smoke.json`, and
//! exits nonzero on any violation — the serving layer's `check-mem`.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};

use anyhow::{bail, ensure, Context, Result};
use qbound::backend::kernels;
use qbound::backend::lowering::LoweredPlan;
use qbound::backend::BackendKind;
use qbound::cli::{Args, CmdSpec};
use qbound::eval::Dataset;
use qbound::memory::{FootprintModel, StorageMode};
use qbound::nets::{arch, ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::serve::autoscale::AutoscaleOptions;
use qbound::serve::frontier::Frontier;
use qbound::serve::{self, ServeOptions, Server};
use qbound::util;
use qbound::util::json::Json;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("serve", "footprint-budgeted HTTP inference daemon")
        .opt("addr", "bind address (smoke mode always uses an ephemeral port)", "127.0.0.1:8484")
        .opt("workers", "worker threads (0 = one per core; smoke default 2)", "0")
        .opt("queue-depth", "max in-flight requests before 429 backpressure", "64")
        .opt(
            "mem-budget-mb",
            "executor-cache budget in MiB (0 = auto: daemon fits every net at fp32, \
             smoke picks a tight budget that forces evictions)",
            "0",
        )
        .opt("backend", "execution backend: reference | fast | pjrt (default: env)", "")
        .opt("storage", "activation storage: f32 | packed (default: env)", "")
        .opt("max-body-kb", "request-body cap in KiB (413 beyond it)", "64")
        .opt("trace-dir", "span tracing: write TRACE_serve.json here on shutdown", "")
        .opt(
            "store-dir",
            "packed-weight store directory (default: QBOUND_STORE_DIR; empty = no store): \
             warm restarts skip re-packing and same-weight executors share one mapping",
            "",
        )
        .flag(
            "autoscale",
            "enable the precision-autoscaling controller (loads FRONTIER_<net>.json from \
             --frontier-dir; see `qbound frontier` and docs/AUTOSCALING.md)",
        )
        .opt("frontier-dir", "autoscale: directory holding FRONTIER_<net>.json ladders", "bench-out")
        .opt(
            "accuracy-floor",
            "autoscale: max relative accuracy loss vs fp32 any served rung may have",
            "0.01",
        )
        .opt("high-water", "autoscale: pressure above this degrades one rung", "0.75")
        .opt("low-water", "autoscale: pressure below this recovers one rung", "0.25")
        .opt("burst-ticks", "autoscale: consecutive hot ticks before degrading", "2")
        .opt("hysteresis-ticks", "autoscale: consecutive calm ticks before recovering", "3")
        .opt("tick-ms", "autoscale: controller sampling period in milliseconds", "200")
        .opt(
            "p99-slo-ms",
            "autoscale: p99 latency SLO in ms; above 0, p99/slo joins queue occupancy \
             as a pressure signal",
            "0",
        )
        .flag("smoke", "run the self-driving smoke workload and exit")
        .flag(
            "expect-warm",
            "smoke: assert a warm start against --store-dir (zero packs; reads the cold \
             run's STORE_stats.json and rewrites it with the cold/warm pair)",
        )
        .opt("smoke-requests", "classification requests the smoke workload replays", "48")
        .opt("slack-mb", "smoke: process-overhead slack for the RSS assertion", "192")
        .opt("slo-ms", "smoke: p99 latency SLO in milliseconds", "5000")
        .opt("out-dir", "smoke: directory for the SERVE_smoke.json artifact", "bench-out");
    let a = spec.parse(args)?;
    let backend = BackendKind::from_arg_or_env(a.str("backend"))?;
    let storage = StorageMode::from_arg_or_env(a.str("storage"))?;
    if a.flag("smoke") {
        if a.flag("autoscale") {
            run_smoke_autoscale(&a, backend, storage)
        } else {
            run_smoke(&a, backend, storage)
        }
    } else {
        run_daemon(&a, backend, storage)
    }
}

/// The `--autoscale` knob bundle (None when the flag is off); bad
/// combinations fail here, before the daemon binds.
fn autoscale_options(a: &Args) -> Result<Option<AutoscaleOptions>> {
    if !a.flag("autoscale") {
        return Ok(None);
    }
    let opts = AutoscaleOptions {
        frontier_dir: a.str("frontier-dir").to_string(),
        accuracy_floor: a.f64("accuracy-floor")?,
        high_water: a.f64("high-water")?,
        low_water: a.f64("low-water")?,
        burst_ticks: a.usize("burst-ticks")?,
        hysteresis_ticks: a.usize("hysteresis-ticks")?,
        tick_ms: a.usize("tick-ms")? as u64,
        p99_slo_us: a.f64("p99-slo-ms")? * 1000.0,
    };
    opts.validate()?;
    Ok(Some(opts))
}

/// MiB CLI value -> bytes.
fn mib(v: f64) -> f64 {
    v * 1024.0 * 1024.0
}

/// The `--trace-dir` value as the server option (empty = disabled).
fn trace_dir(a: &Args) -> Option<String> {
    let d = a.str("trace-dir");
    (!d.is_empty()).then(|| d.to_string())
}

/// Resolve the packed-weight store directory: `--store-dir`, falling
/// back to `QBOUND_STORE_DIR`. The CLI is the only place the
/// environment is consulted — the server takes the resolved value.
fn store_dir(a: &Args) -> Option<String> {
    let d = a.str("store-dir");
    if !d.is_empty() {
        return Some(d.to_string());
    }
    std::env::var("QBOUND_STORE_DIR").ok().filter(|v| !v.is_empty())
}

fn run_daemon(a: &Args, backend: BackendKind, storage: StorageMode) -> Result<()> {
    let dir = util::artifacts_dir()?;
    let budget = match a.f64("mem-budget-mb")? {
        b if b > 0.0 => mib(b),
        _ => {
            // Auto: room for every indexed net's fp32 executor at once —
            // a budget that never refuses a sane single-tenant workload.
            let index = ArtifactIndex::load(&dir)?;
            let mut total = 0.0;
            for net in &index.nets {
                if let Some(e) = fp32_envelope(&dir, net)? {
                    total += e;
                }
            }
            total.max(mib(1.0))
        }
    };
    let opts = ServeOptions {
        addr: a.str("addr").to_string(),
        workers: a.usize("workers")?,
        queue_depth: a.usize("queue-depth")?,
        mem_budget_bytes: budget,
        backend,
        storage,
        max_body_bytes: a.usize("max-body-kb")? * 1024,
        trace_dir: trace_dir(a),
        store_dir: store_dir(a),
        autoscale: autoscale_options(a)?,
    };
    // Resolve kernel dispatch up front: a bad QBOUND_KERNEL fails the
    // launch cleanly, and the startup banner reports the variant.
    let kernel = kernels::init()?;
    let server = Server::start(&dir, &opts)?;
    let addr = server.addr();
    println!("qbound serve — listening on http://{addr}");
    println!(
        "  backend {}  storage {}  kernel {}",
        backend.label(),
        storage.label(),
        kernel.label()
    );
    println!("  mem budget {}  queue depth {}", util::human_bytes(budget), opts.queue_depth);
    match &opts.store_dir {
        Some(d) => println!("  packed-weight store: {d}"),
        None => println!("  packed-weight store: disabled (--store-dir / QBOUND_STORE_DIR)"),
    }
    match &opts.autoscale {
        Some(ao) => println!(
            "  autoscale: on (frontiers {}, floor {}, watermarks {}/{}, tick {} ms)",
            ao.frontier_dir, ao.accuracy_floor, ao.low_water, ao.high_water, ao.tick_ms
        ),
        None => println!("  autoscale: off (--autoscale + `qbound frontier` to enable)"),
    }
    println!(
        "  endpoints: GET /healthz  GET /v1/nets  GET /v1/stats  GET /metrics  \
         POST /v1/classify"
    );
    println!(
        "  try: curl -s http://{addr}/v1/classify -X POST \
         -d '{{\"net\":\"lenet\",\"weights\":\"1.8\",\"data\":\"10.4\",\"index\":7}}'"
    );
    server.join();
    Ok(())
}

/// The fused-executor envelope of `net` at fp32, or `None` when the net
/// has no registered architecture (it won't be served either).
fn fp32_envelope(dir: &std::path::Path, net: &str) -> Result<Option<f64>> {
    let Some(arch) = arch::get(net) else { return Ok(None) };
    let m = NetManifest::load(dir, net)?;
    let plan = LoweredPlan::new(&arch, None)?;
    let fpm = FootprintModel::new(&m);
    let cfg = PrecisionConfig::fp32(m.n_layers());
    let win = plan.fused_window_elems(1);
    Ok(Some(fpm.fused_envelope(&cfg, win, &plan.weight_pad_elems)))
}

// ---- smoke mode ---------------------------------------------------------

/// One servable net, loaded alongside the daemon for oracle checks and
/// envelope math (same public APIs the server uses internally).
struct SmokeNet {
    name: String,
    manifest: NetManifest,
    dataset: Dataset,
    fpm: FootprintModel,
    window_f32_elems: usize,
    weight_pad_elems: Vec<usize>,
}

impl SmokeNet {
    fn load(dir: &std::path::Path, name: &str) -> Result<SmokeNet> {
        let manifest = NetManifest::load(dir, name)?;
        let a = arch::get(name)
            .ok_or_else(|| anyhow::anyhow!("no architecture registered for {name:?}"))?;
        let plan = LoweredPlan::new(&a, None)?;
        Ok(SmokeNet {
            name: name.to_string(),
            dataset: Dataset::load(&manifest)?,
            fpm: FootprintModel::new(&manifest),
            window_f32_elems: plan.fused_window_elems(1),
            weight_pad_elems: plan.weight_pad_elems.clone(),
            manifest,
        })
    }

    fn envelope(&self, cfg: &PrecisionConfig) -> f64 {
        self.fpm.fused_envelope(cfg, self.window_f32_elems, &self.weight_pad_elems)
    }

    fn cfg(&self, wfmt: QFormat, dfmt: QFormat) -> PrecisionConfig {
        PrecisionConfig::uniform(self.manifest.n_layers(), wfmt, dfmt)
    }
}

fn run_smoke(a: &Args, backend: BackendKind, storage: StorageMode) -> Result<()> {
    let dir = util::artifacts_dir()?;
    let index = ArtifactIndex::load(&dir)?;
    let mut nets = Vec::new();
    for name in ["lenet", "convnet"] {
        ensure!(index.nets.iter().any(|n| n == name), "smoke needs {name} artifacts");
        nets.push(SmokeNet::load(&dir, name)?);
    }
    // Rotating weight formats × two nets = 8 distinct executor keys;
    // each key is requested twice in a row so a correctly sized cache
    // must produce hits AND evictions under the tight budget below.
    let wfmts = [QFormat::new(1, 8), QFormat::new(2, 7), QFormat::new(1, 6), QFormat::new(3, 4)];
    let dfmt = QFormat::new(10, 4);
    let max_env = nets
        .iter()
        .flat_map(|n| wfmts.iter().map(|w| n.envelope(&n.cfg(*w, dfmt))))
        .fold(0f64, f64::max);
    let budget = match a.f64("mem-budget-mb")? {
        b if b > 0.0 => mib(b),
        // Tight auto budget: every workload config fits alone, only ~2
        // executors fit together — the 8-key rotation must evict.
        _ => max_env * 2.5,
    };
    ensure!(budget >= max_env, "--mem-budget-mb admits no workload config");

    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        workers: match a.usize("workers")? {
            0 => 2,
            w => w,
        },
        queue_depth: a.usize("queue-depth")?,
        mem_budget_bytes: budget,
        backend,
        storage,
        max_body_bytes: a.usize("max-body-kb")? * 1024,
        trace_dir: trace_dir(a),
        store_dir: store_dir(a),
        autoscale: None,
    };
    ensure!(
        !a.flag("expect-warm") || opts.store_dir.is_some(),
        "--expect-warm needs --store-dir (or QBOUND_STORE_DIR)"
    );
    // Start-to-ready: bind + load manifests + one sweep that touches
    // every workload config once, so every executor's weights are
    // packed (cold) or store-loaded (warm) inside the measured window.
    let t_ready = std::time::Instant::now();
    let server = Server::start(&dir, &opts)?;
    let addr = server.addr();
    println!(
        "serve --smoke — live endpoint {addr}, backend {}, storage {}, kernel {}, budget {}",
        backend.label(),
        storage.label(),
        kernels::init()?.label(),
        util::human_bytes(budget)
    );

    let (st, health) = http_get(addr, "/healthz")?;
    ensure!(st == 200 && health.get("ok").and_then(Json::as_bool) == Some(true), "healthz: {st}");
    for net in &nets {
        for wfmt in &wfmts {
            let body = format!(
                "{{\"net\":\"{}\",\"weights\":\"{}\",\"data\":\"{}\",\"index\":0}}",
                net.name, wfmt, dfmt
            );
            let (st, _) = http_post(addr, "/v1/classify", &body)?;
            ensure!(st == 200, "ready sweep ({body}): status {st}");
        }
    }
    let ready_us = t_ready.elapsed().as_micros() as f64;

    // Mixed workload over live TCP, every answer checked against a
    // freshly loaded reference-backend oracle.
    let oracle = BackendKind::Reference.create()?;
    let n_req = a.usize("smoke-requests")?;
    ensure!(n_req >= 16, "--smoke-requests too small to exercise the cache");
    let mut checked = 0usize;
    for i in 0..n_req {
        let net = &nets[i % nets.len()];
        let wfmt = wfmts[(i / 4) % wfmts.len()];
        let idx = i % net.dataset.n;
        let body = format!(
            "{{\"net\":\"{}\",\"weights\":\"{}\",\"data\":\"{}\",\"index\":{}}}",
            net.name, wfmt, dfmt, idx
        );
        let (st, resp) = http_post(addr, "/v1/classify", &body)?;
        ensure!(st == 200, "classify #{i} ({body}): status {st} {resp}");
        let pred = resp.get("pred").and_then(Json::as_usize).context("classify: no pred")?;
        let want = serve::reference_prediction(
            &net.manifest,
            &net.dataset,
            oracle.as_ref(),
            &net.cfg(wfmt, dfmt),
            idx,
        )?;
        ensure!(pred == want, "classify #{i}: served pred {pred} != reference {want} ({body})");
        checked += 1;
    }

    // Pipelined keep-alive pair on one connection.
    let (s1, s2) = http_pipelined_pair(
        addr,
        &format!(
            "{{\"net\":\"{}\",\"weights\":\"1.8\",\"data\":\"{dfmt}\",\"index\":0}}",
            nets[0].name
        ),
    )?;
    ensure!(s1 == 200 && s2 == 200, "pipelined pair: {s1}/{s2}");

    // Protocol error probes against the live endpoint.
    let (st, _) = http_post(addr, "/v1/classify", "{not json")?;
    ensure!(st == 400, "malformed body probe: {st}");
    let (st, _) = http_post(addr, "/v1/classify", "{\"net\":\"nope\"}")?;
    ensure!(st == 404, "unknown-net probe: {st}");
    let (st, _) = http_get(addr, "/v1/classify")?;
    ensure!(st == 405, "method probe: {st}");
    let st = http_oversized_probe(addr, opts.max_body_bytes + 1)?;
    ensure!(st == 413, "oversized-body probe: {st}");
    // Budget refusal: any net whose fp32 envelope can't fit the budget
    // must be refused with 507 without evicting the residents.
    let mut probed_507 = false;
    for net in &nets {
        if net.envelope(&net.cfg(QFormat::FP32, QFormat::FP32)) > budget {
            let body = format!("{{\"net\":\"{}\"}}", net.name);
            let (st, _) = http_post(addr, "/v1/classify", &body)?;
            ensure!(st == 507, "over-budget probe on {}: {st}", net.name);
            probed_507 = true;
            break;
        }
    }

    // Prometheus exposition after traffic: the request histogram and
    // the per-layer series must both be populated.
    let (st, expo) = http_get_text(addr, "/metrics")?;
    ensure!(st == 200, "metrics: {st}");
    ensure!(!expo.trim().is_empty(), "metrics: empty exposition");
    for series in [
        "# TYPE",
        "qbound_http_requests_total",
        "qbound_request_latency_us_bucket",
        "qbound_layer_us",
    ] {
        ensure!(expo.contains(series), "metrics exposition is missing {series:?}:\n{expo}");
    }

    // Stats, SLO and the memory bound.
    let (st, stats) = http_get(addr, "/v1/stats")?;
    ensure!(st == 200, "stats: {st}");
    let kernel = stats
        .get("kernel")
        .and_then(Json::as_str)
        .context("stats: no kernel variant")?
        .to_string();
    let p99 = stats.get("latency_us_p99").and_then(Json::as_f64).context("stats: no p99")?;
    let p50 = stats.get("latency_us_p50").and_then(Json::as_f64).context("stats: no p50")?;
    let p95 = stats.get("latency_us_p95").and_then(Json::as_f64).context("stats: no p95")?;
    let slo_us = a.f64("slo-ms")? * 1000.0;
    ensure!(p99 <= slo_us, "p99 {p99} us over the {slo_us} us SLO");
    let cache = stats.get("cache").context("stats: no cache block")?;
    let hits = cache.get("hits").and_then(Json::as_u64).unwrap_or(0);
    let evictions = cache.get("evictions").and_then(Json::as_u64).unwrap_or(0);
    let resident = cache.get("resident_bytes").and_then(Json::as_f64).unwrap_or(f64::MAX);
    ensure!(hits > 0, "vacuous smoke: the workload produced no cache hits");
    ensure!(evictions > 0, "vacuous smoke: the tight budget produced no evictions");
    ensure!(resident <= budget, "resident {resident} B over budget {budget} B");
    let peak_rss = util::peak_rss_bytes().context("no /proc peak RSS on this platform")?;
    let slack = mib(a.f64("slack-mb")?);
    ensure!(
        (peak_rss as f64) <= budget + slack,
        "peak RSS {} over --mem-budget {} + slack {}",
        util::human_bytes(peak_rss as f64),
        util::human_bytes(budget),
        util::human_bytes(slack)
    );
    ensure!(checked == n_req, "vacuous smoke: {checked}/{n_req} predictions checked");

    // Archive the record next to BENCH_*/MEM_* artifacts.
    let doc = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("mode", Json::str("smoke")),
        ("backend", Json::str(backend.label())),
        ("storage", Json::str(storage.label())),
        ("kernel", Json::str(kernel.as_str())),
        ("requests_checked", Json::num(checked as f64)),
        ("probed_507", Json::Bool(probed_507)),
        ("mem_budget_bytes", Json::num(budget)),
        ("slack_bytes", Json::num(slack)),
        ("peak_rss_bytes", Json::num(peak_rss as f64)),
        ("slo_us", Json::num(slo_us)),
        ("ready_us", Json::num(ready_us)),
        ("stats", stats.clone()),
    ]);
    let path = std::path::PathBuf::from(a.str("out-dir")).join("SERVE_smoke.json");
    util::write_file(&path, doc.pretty().as_bytes())?;

    // Packed-weight store verdict + STORE_stats.json artifact. The cold
    // run records its pack count and start-to-ready time; the warm run
    // (`--expect-warm`, same --store-dir, fresh process) must load every
    // bitstream from disk — zero packs, hard — and not be slower than
    // the cold start beyond generous CI noise slack.
    if let Some(sdir) = &opts.store_dir {
        let store_stats = stats.get("store").cloned().context("stats: no store block")?;
        let packs = store_stats.get("packs").and_then(Json::as_f64).context("store: no packs")?;
        let run = Json::obj(vec![
            ("dir", Json::str(sdir.clone())),
            ("backend", Json::str(backend.label())),
            ("storage", Json::str(storage.label())),
            ("ready_us", Json::num(ready_us)),
            ("requests_checked", Json::num(checked as f64)),
            ("store", store_stats),
            ("cache", stats.get("cache").cloned().unwrap_or(Json::Null)),
        ]);
        let spath = std::path::PathBuf::from(a.str("out-dir")).join("STORE_stats.json");
        let record = if a.flag("expect-warm") {
            let prev = std::fs::read_to_string(&spath)
                .with_context(|| format!("--expect-warm: no cold-run {}", spath.display()))?;
            let prev = Json::parse(&prev).map_err(anyhow::Error::from)?;
            let cold = prev.get("cold").cloned().context("--expect-warm: no cold record")?;
            let cold_packs = cold
                .get("store")
                .and_then(|s| s.get("packs"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0);
            ensure!(cold_packs > 0.0, "vacuous warm check: the cold run recorded no packs");
            ensure!(
                packs == 0.0,
                "warm start re-packed {packs:.0} bitstreams; the store at {sdir} should \
                 already hold all of them"
            );
            let cold_ready = cold.get("ready_us").and_then(Json::as_f64).unwrap_or(0.0);
            let max_warm = cold_ready * 1.5 + 2_000_000.0;
            ensure!(
                ready_us <= max_warm,
                "warm start-to-ready {ready_us:.0} us over the bound {max_warm:.0} us \
                 (cold was {cold_ready:.0} us)"
            );
            println!(
                "  warm start: 0 packs (cold packed {cold_packs:.0}), ready {:.0} ms \
                 (cold {:.0} ms)",
                ready_us / 1000.0,
                cold_ready / 1000.0
            );
            Json::obj(vec![("schema", Json::num(1.0)), ("cold", cold), ("warm", run)])
        } else {
            println!("  cold start: {packs:.0} packs, ready {:.0} ms", ready_us / 1000.0);
            Json::obj(vec![("schema", Json::num(1.0)), ("cold", run)])
        };
        util::write_file(&spath, record.pretty().as_bytes())?;
        println!("  store json -> {}", spath.display());
    }

    server.shutdown();
    println!("  {checked} live requests checked against the reference oracle");
    println!("  latency p50 {p50:.0} us  p95 {p95:.0} us  p99 {p99:.0} us (SLO {slo_us:.0} us)");
    let resident_h = util::human_bytes(resident);
    println!("  cache hits {hits}  evictions {evictions}  resident {resident_h}");
    println!(
        "  peak RSS {} within budget {} + slack {}",
        util::human_bytes(peak_rss as f64),
        util::human_bytes(budget),
        util::human_bytes(slack)
    );
    println!("  serve json -> {}", path.display());
    Ok(())
}

// ---- autoscale smoke leg ------------------------------------------------

/// `serve --smoke --autoscale`: start the daemon with the controller on,
/// hammer it from concurrent clients until it degrades at least one
/// rung, drain until it recovers, then assert the transition record —
/// ≥1 degrade, ≥1 recovery, no served rung past the accuracy floor,
/// zero store re-packs across the swaps — and archive
/// `AUTOSCALE_smoke.json`. Every observed rung's predictions are
/// checked against the reference oracle at that rung's config.
fn run_smoke_autoscale(a: &Args, backend: BackendKind, storage: StorageMode) -> Result<()> {
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::Mutex;
    use std::time::{Duration, Instant};

    let dir = util::artifacts_dir()?;
    let net = SmokeNet::load(&dir, "lenet")?;
    let fdir = std::path::PathBuf::from(a.str("frontier-dir"));
    let fpath = fdir.join(Frontier::file_name("lenet"));
    let frontier = Frontier::load(&fpath).with_context(|| {
        format!(
            "autoscale smoke needs {} — run `qbound frontier --net lenet` first",
            fpath.display()
        )
    })?;
    let floor = a.f64("accuracy-floor")?;
    let usable = frontier.usable_rungs(floor);
    ensure!(
        usable >= 2,
        "autoscale smoke needs >= 2 rungs within floor {floor}, {} has {usable} \
         (loosen --accuracy-floor or re-run `qbound frontier` with more images)",
        fpath.display()
    );

    // Every usable rung must fit the budget alone: the burst has to
    // degrade because of queue pressure, never admission refusals.
    let max_env = frontier.rungs[..usable]
        .iter()
        .map(|r| net.envelope(&r.cfg))
        .fold(0f64, f64::max);
    let budget = match a.f64("mem-budget-mb")? {
        b if b > 0.0 => mib(b),
        _ => max_env * 2.5,
    };
    ensure!(budget >= max_env, "--mem-budget-mb admits no usable rung");

    let auto_opts = autoscale_options(a)?.expect("--autoscale is set on this path");
    let opts = ServeOptions {
        addr: "127.0.0.1:0".to_string(),
        // One worker and a tiny queue: a concurrent burst drives the
        // occupancy fraction to 1.0 within a tick or two.
        workers: 1,
        queue_depth: 4,
        mem_budget_bytes: budget,
        backend,
        storage,
        max_body_bytes: a.usize("max-body-kb")? * 1024,
        trace_dir: trace_dir(a),
        store_dir: store_dir(a),
        autoscale: Some(auto_opts.clone()),
    };
    let t_ready = std::time::Instant::now();
    let server = Server::start(&dir, &opts)?;
    let addr = server.addr();
    println!(
        "serve --smoke --autoscale — live endpoint {addr}, backend {}, {} rung(s) \
         ({usable} usable at floor {floor}), budget {}",
        backend.label(),
        frontier.rungs.len(),
        util::human_bytes(budget)
    );

    let (st, health) = http_get(addr, "/healthz")?;
    ensure!(st == 200 && health.get("ok").and_then(Json::as_bool) == Some(true), "healthz: {st}");
    // One quiet classify: the daemon must answer at rung 0 (widest) and
    // say so in the response.
    let (st, resp) = http_post(addr, "/v1/classify", "{\"net\":\"lenet\",\"index\":0}")?;
    ensure!(st == 200, "ready classify: status {st} {resp}");
    ensure!(
        resp.get("rung").and_then(Json::as_u64) == Some(0),
        "expected rung 0 before the burst, got {resp}"
    );
    let ready_us = t_ready.elapsed().as_micros() as f64;

    let (st, stats0) = http_get(addr, "/v1/stats")?;
    ensure!(st == 200, "stats: {st}");
    let store_on = stats0.at(&["store", "enabled"]).as_bool() == Some(true);
    let packs_ready = stats0.at(&["store", "packs"]).as_f64().unwrap_or(0.0);

    // Burst phase: concurrent clients keep the queue saturated until
    // /v1/stats shows a degrade, then linger briefly so responses at
    // the narrow rung are actually observed.
    let stop = AtomicBool::new(false);
    let observed: Mutex<Vec<(usize, usize, usize)>> = Mutex::new(Vec::new()); // (rung, index, pred)
    let mut degraded = false;
    std::thread::scope(|s| {
        for _ in 0..8 {
            s.spawn(|| {
                let mut i = 0usize;
                while !stop.load(Ordering::Relaxed) {
                    let idx = i % 4;
                    i += 1;
                    let body = format!("{{\"net\":\"lenet\",\"index\":{idx}}}");
                    // 429s under saturation are the point, not a failure.
                    if let Ok((200, resp)) = http_post(addr, "/v1/classify", &body) {
                        if let (Some(r), Some(p)) = (
                            resp.get("rung").and_then(Json::as_usize),
                            resp.get("pred").and_then(Json::as_usize),
                        ) {
                            observed.lock().unwrap().push((r, idx, p));
                        }
                    }
                }
            });
        }
        let deadline = Instant::now() + Duration::from_secs(60);
        while Instant::now() < deadline {
            std::thread::sleep(Duration::from_millis(50));
            if let Ok((200, stats)) = http_get(addr, "/v1/stats") {
                let rung = stats
                    .at(&["autoscale", "nets", "lenet", "active_rung"])
                    .as_u64()
                    .unwrap_or(0);
                if rung >= 1 {
                    degraded = true;
                    break;
                }
            }
        }
        // Grace window: keep bursting until a narrow-rung answer lands.
        let grace = Instant::now() + Duration::from_secs(5);
        while degraded && Instant::now() < grace {
            if observed.lock().unwrap().iter().any(|(r, _, _)| *r >= 1) {
                break;
            }
            std::thread::sleep(Duration::from_millis(50));
        }
        stop.store(true, Ordering::Relaxed);
    });
    ensure!(degraded, "burst phase never degraded the rung (see --high-water/--burst-ticks)");

    // Drain phase: no traffic — the hysteresis window must bring the
    // rung back to 0 and count a recovery.
    let mut recovered = false;
    let deadline = Instant::now() + Duration::from_secs(60);
    while Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(100));
        if let Ok((200, stats)) = http_get(addr, "/v1/stats") {
            let rung = stats
                .at(&["autoscale", "nets", "lenet", "active_rung"])
                .as_u64()
                .unwrap_or(u64::MAX);
            let recoveries =
                stats.at(&["autoscale", "recoveries"]).as_u64().unwrap_or(0);
            if rung == 0 && recoveries >= 1 {
                recovered = true;
                break;
            }
        }
    }
    ensure!(recovered, "drain phase never recovered to rung 0");

    // Final record: transitions, floor compliance, zero re-packs.
    let (st, stats) = http_get(addr, "/v1/stats")?;
    ensure!(st == 200, "final stats: {st}");
    let degrades = stats.at(&["autoscale", "degrades"]).as_u64().unwrap_or(0);
    let recoveries = stats.at(&["autoscale", "recoveries"]).as_u64().unwrap_or(0);
    ensure!(degrades >= 1, "no degrade transition recorded");
    ensure!(recoveries >= 1, "no recovery transition recorded");
    let transitions = stats
        .at(&["autoscale", "transitions"])
        .as_arr()
        .context("stats: no transition log")?
        .to_vec();
    ensure!(!transitions.is_empty(), "empty transition log after observed transitions");
    for t in &transitions {
        let to = t.get("to").and_then(Json::as_usize).context("transition: no \"to\"")?;
        ensure!(to < usable, "transition selected rung {to}, outside the {usable} usable");
        let rel = frontier.rungs[to].rel_err;
        ensure!(
            rel <= floor + 1e-12,
            "transition to rung {to} violates the accuracy floor ({rel} > {floor})"
        );
    }
    let packs_final = stats.at(&["store", "packs"]).as_f64().unwrap_or(0.0);
    if store_on {
        ensure!(
            packs_final == packs_ready,
            "rung swaps re-packed weights ({packs_ready:.0} -> {packs_final:.0} packs); \
             the pre-warm should have covered every usable rung"
        );
    }

    // Oracle: check served predictions at every observed rung against
    // the reference backend running that rung's exact config.
    let samples = observed.into_inner().unwrap_or_default();
    let mut by_rung: std::collections::BTreeMap<usize, Vec<(usize, usize)>> = Default::default();
    for (r, idx, pred) in samples {
        by_rung.entry(r).or_default().push((idx, pred));
    }
    ensure!(
        by_rung.keys().any(|r| *r >= 1),
        "no response was observed at a degraded rung (burst raced the stop signal)"
    );
    let oracle = BackendKind::Reference.create()?;
    let mut checked = 0usize;
    for (r, entries) in &by_rung {
        for (idx, pred) in entries.iter().take(3) {
            let want = serve::reference_prediction(
                &net.manifest,
                &net.dataset,
                oracle.as_ref(),
                &frontier.rungs[*r].cfg,
                *idx,
            )?;
            ensure!(
                *pred == want,
                "rung {r} index {idx}: served pred {pred} != reference {want}"
            );
            checked += 1;
        }
    }

    let rungs_observed: Vec<Json> =
        by_rung.keys().map(|r| Json::num(*r as f64)).collect();
    let doc = Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("mode", Json::str("autoscale-smoke")),
        ("backend", Json::str(backend.label())),
        ("storage", Json::str(storage.label())),
        ("frontier", Json::str(fpath.display().to_string())),
        ("rungs", Json::num(frontier.rungs.len() as f64)),
        ("usable_rungs", Json::num(usable as f64)),
        ("accuracy_floor", Json::num(floor)),
        ("high_water", Json::num(auto_opts.high_water)),
        ("low_water", Json::num(auto_opts.low_water)),
        ("burst_ticks", Json::num(auto_opts.burst_ticks as f64)),
        ("hysteresis_ticks", Json::num(auto_opts.hysteresis_ticks as f64)),
        ("tick_ms", Json::num(auto_opts.tick_ms as f64)),
        ("mem_budget_bytes", Json::num(budget)),
        ("ready_us", Json::num(ready_us)),
        ("degrades", Json::num(degrades as f64)),
        ("recoveries", Json::num(recoveries as f64)),
        ("rungs_observed", Json::arr(rungs_observed)),
        ("requests_checked", Json::num(checked as f64)),
        ("store_enabled", Json::Bool(store_on)),
        ("packs_ready", Json::num(packs_ready)),
        ("packs_final", Json::num(packs_final)),
        ("transitions", Json::Arr(transitions)),
    ]);
    let path = std::path::PathBuf::from(a.str("out-dir")).join("AUTOSCALE_smoke.json");
    util::write_file(&path, doc.pretty().as_bytes())?;

    server.shutdown();
    println!("  degrades {degrades}  recoveries {recoveries}  (usable rungs {usable})");
    println!(
        "  store packs ready/final: {packs_ready:.0}/{packs_final:.0}{}",
        if store_on { " (zero re-pack swaps)" } else { " (store off)" }
    );
    let rung_list: Vec<usize> = by_rung.keys().copied().collect();
    println!("  {checked} predictions oracle-checked across rungs {rung_list:?}");
    println!("  autoscale json -> {}", path.display());
    Ok(())
}

// ---- minimal smoke HTTP client (pure std) -------------------------------

fn http_get(addr: SocketAddr, path: &str) -> Result<(u16, Json)> {
    let req = format!("GET {path} HTTP/1.1\r\nHost: smoke\r\nConnection: close\r\n\r\n");
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(req.as_bytes())?;
    read_response(&mut BufReader::new(stream))
}

/// `GET` returning the raw body (the `/metrics` text exposition is not
/// JSON).
fn http_get_text(addr: SocketAddr, path: &str) -> Result<(u16, String)> {
    let req = format!("GET {path} HTTP/1.1\r\nHost: smoke\r\nConnection: close\r\n\r\n");
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(req.as_bytes())?;
    read_response_text(&mut BufReader::new(stream))
}

fn http_post(addr: SocketAddr, path: &str, body: &str) -> Result<(u16, Json)> {
    let req = format!(
        "POST {path} HTTP/1.1\r\nHost: smoke\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    );
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(req.as_bytes())?;
    read_response(&mut BufReader::new(stream))
}

/// Two identical classify requests written back-to-back on one
/// keep-alive connection before any response is read — exercises the
/// daemon's pipelining over a real socket.
fn http_pipelined_pair(addr: SocketAddr, body: &str) -> Result<(u16, u16)> {
    let one = format!(
        "POST /v1/classify HTTP/1.1\r\nHost: smoke\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(format!("{one}{one}").as_bytes())?;
    let mut reader = BufReader::new(stream);
    let (s1, _) = read_response(&mut reader)?;
    let (s2, _) = read_response(&mut reader)?;
    Ok((s1, s2))
}

/// Declare a body one byte over the cap without sending it; the daemon
/// must refuse at the header stage with 413.
fn http_oversized_probe(addr: SocketAddr, declared: usize) -> Result<u16> {
    let req = format!(
        "POST /v1/classify HTTP/1.1\r\nHost: smoke\r\nContent-Length: {declared}\r\n\r\n"
    );
    let mut stream = TcpStream::connect(addr)?;
    stream.write_all(req.as_bytes())?;
    let (st, _) = read_response(&mut BufReader::new(stream))?;
    Ok(st)
}

/// Parse one `HTTP/1.1` response: status + JSON body (Null when empty).
fn read_response(r: &mut impl BufRead) -> Result<(u16, Json)> {
    let (status, body) = read_response_text(r)?;
    if body.is_empty() {
        return Ok((status, Json::Null));
    }
    Ok((status, Json::parse(&body).map_err(anyhow::Error::from)?))
}

/// Parse one `HTTP/1.1` response: status + raw body text.
fn read_response_text(r: &mut impl BufRead) -> Result<(u16, String)> {
    let mut line = String::new();
    r.read_line(&mut line)?;
    let status: u16 = line
        .split_whitespace()
        .nth(1)
        .with_context(|| format!("bad status line {line:?}"))?
        .parse()?;
    let mut content_length = 0usize;
    loop {
        let mut h = String::new();
        if r.read_line(&mut h)? == 0 {
            bail!("eof inside response headers");
        }
        let t = h.trim_end();
        if t.is_empty() {
            break;
        }
        if let Some(v) = t.to_ascii_lowercase().strip_prefix("content-length:") {
            content_length = v.trim().parse()?;
        }
    }
    let mut body = vec![0u8; content_length];
    r.read_exact(&mut body)?;
    Ok((status, String::from_utf8(body)?))
}
