//! `qbound info` — artifact inventory.

use anyhow::Result;
use qbound::cli::CmdSpec;
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::report::Table;
use qbound::util;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("info", "artifact inventory: nets, baselines, sizes")
        .flag("layers", "also print per-layer detail");
    let a = spec.parse(args)?;

    let dir = util::artifacts_dir()?;
    let index = ArtifactIndex::load(&dir)?;
    println!("artifacts: {}  (batch={}, quick={})", dir.display(), index.batch, index.quick);

    let mut t = Table::new(
        "networks",
        &["net", "dataset", "layers", "weights", "MACs/img", "baseline top-1"],
    );
    for name in &index.nets {
        let m = NetManifest::load(&dir, name)?;
        t.row(vec![
            m.name.clone(),
            m.dataset.clone(),
            m.n_layers().to_string(),
            util::human_count(m.total_weights() as f64),
            util::human_count(m.total_macs() as f64),
            format!("{:.4}", m.baseline_top1),
        ]);
    }
    print!("{}", t.text());

    if a.flag("layers") {
        for name in &index.nets {
            let m = NetManifest::load(&dir, name)?;
            let mut lt = Table::new(
                &format!("{name} layers"),
                &["layer", "kind", "in", "out", "weights", "MACs", "stages"],
            );
            for l in &m.layers {
                lt.row(vec![
                    l.name.clone(),
                    l.kind.clone(),
                    l.in_elems.to_string(),
                    l.out_elems.to_string(),
                    l.weight_elems.to_string(),
                    util::human_count(l.macs as f64),
                    l.stages.join(","),
                ]);
            }
            print!("{}", lt.text());
        }
    }
    Ok(())
}
