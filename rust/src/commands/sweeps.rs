//! `qbound sweep-uniform` / `qbound sweep-layer`.

use anyhow::Result;
use qbound::backend::BackendKind;
use qbound::cli::CmdSpec;
use qbound::coordinator::Coordinator;
use qbound::nets::NetManifest;
use qbound::report::{Chart, Table};
use qbound::search::{perlayer, uniform, Param};
use qbound::util;

fn parse_param(s: &str) -> Result<Param> {
    Ok(match s {
        "weight-f" | "wf" => Param::WeightF,
        "data-i" | "di" => Param::DataI,
        "data-f" | "df" => Param::DataF,
        other => anyhow::bail!("unknown param {other:?} (weight-f | data-i | data-f)"),
    })
}

pub fn run_uniform(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("sweep-uniform", "uniform representation sweep (Fig 2)")
        .opt("net", "network name", "lenet")
        .opt("param", "weight-f | data-i | data-f", "weight-f")
        .opt("min", "minimum bits", "1")
        .opt("max", "maximum bits", "12")
        .opt("n-images", "images per evaluation (0 = full)", "0")
        .opt("workers", "worker threads (0 = one per core)", "0")
        .opt(
            "backend",
            "execution backend: reference | fast | pjrt (default: env or reference)",
            "",
        );
    let a = spec.parse(args)?;
    let dir = util::artifacts_dir()?;
    let net = a.str("net").to_string();
    let m = NetManifest::load(&dir, &net)?;
    let param = parse_param(a.str("param"))?;
    let backend = BackendKind::from_arg_or_env(a.str("backend"))?;
    let mut coord = Coordinator::with_backend(&dir, a.usize("workers")?, backend)?;

    let pts = uniform::sweep(
        &mut coord,
        &net,
        m.n_layers(),
        param,
        (a.i32("min")? as i8, a.i32("max")? as i8),
        a.usize("n-images")?,
    )?;
    let mut t = Table::new(
        &format!("{net} — uniform {}", param.label()),
        &["bits", "top-1", "relative"],
    );
    for p in &pts {
        t.row(vec![p.bits.to_string(), format!("{:.4}", p.accuracy), format!("{:.4}", p.relative)]);
    }
    print!("{}", t.text());
    let mut chart = Chart::new(&format!("{net}"), param.label(), "relative accuracy");
    chart.series('*', pts.iter().map(|p| (p.bits as f64, p.relative)).collect());
    print!("{}", chart.render());
    if let Some(b) = uniform::min_bits_within(&pts, 0.01) {
        println!("min bits within 1%: {b}");
    }
    Ok(())
}

pub fn run_layer(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("sweep-layer", "one-layer-at-a-time sweep (Fig 3)")
        .opt("net", "network name", "lenet")
        .opt("layer", "layer index (0-based), or 'all'", "all")
        .opt("param", "weight-f | data-i | data-f", "data-i")
        .opt("min", "minimum bits", "1")
        .opt("max", "maximum bits", "12")
        .opt("n-images", "images per evaluation (0 = full)", "0")
        .opt("workers", "worker threads (0 = one per core)", "0")
        .opt(
            "backend",
            "execution backend: reference | fast | pjrt (default: env or reference)",
            "",
        );
    let a = spec.parse(args)?;
    let dir = util::artifacts_dir()?;
    let net = a.str("net").to_string();
    let m = NetManifest::load(&dir, &net)?;
    let param = parse_param(a.str("param"))?;
    let range = (a.i32("min")? as i8, a.i32("max")? as i8);
    let n_images = a.usize("n-images")?;
    let backend = BackendKind::from_arg_or_env(a.str("backend"))?;
    let mut coord = Coordinator::with_backend(&dir, a.usize("workers")?, backend)?;

    let layers: Vec<usize> = if a.str("layer") == "all" {
        (0..m.n_layers()).collect()
    } else {
        vec![a.usize("layer")?]
    };

    let matrix = perlayer::sweep_all_layers(
        &mut coord,
        &net,
        m.n_layers(),
        &[param],
        range,
        n_images,
    )?;
    let mut t = Table::new(
        &format!("{net} — per-layer {}", param.label()),
        &["layer", "min bits @1%", "series (bits:rel)"],
    );
    for &l in &layers {
        let series = &matrix[0][l];
        t.row(vec![
            m.layers[l].name.clone(),
            uniform::min_bits_within(series, 0.01)
                .map(|b| b.to_string())
                .unwrap_or("-".into()),
            series
                .iter()
                .map(|p| format!("{}:{:.3}", p.bits, p.relative))
                .collect::<Vec<_>>()
                .join(" "),
        ]);
    }
    print!("{}", t.text());
    Ok(())
}
