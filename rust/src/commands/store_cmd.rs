//! `qbound store` — inspect and manage the content-addressed
//! packed-weight store ([`qbound::store`]).
//!
//! Actions:
//!
//! * `ls` — one row per store file: key, payload description,
//!   validation verdict, size, age.
//! * `gc` — remove store files (and stale temp files); `--dry-run`
//!   reports without removing, `--older-than-hours` keeps young files.
//!   Removal never invalidates live mappings in running daemons
//!   (Linux keeps an unlinked file alive until the last mapping
//!   drops), so `gc` is safe to run beside a serving process — at
//!   worst the next cold load re-packs and re-publishes.
//! * `warm` — pre-pack every weight tensor of the indexed networks at
//!   the given uniform weight formats, so a subsequent
//!   `qbound serve --store-dir` (or eval with `QBOUND_STORE_DIR`)
//!   starts with zero pack work.

use std::path::Path;
use std::time::Duration;

use anyhow::{bail, Context, Result};
use qbound::backend::gemm::{pack_b_panels, NR};
use qbound::backend::lowering::{self, LoweredPlan};
use qbound::backend::Variant;
use qbound::cli::{Args, CmdSpec};
use qbound::memory::{PackedBuf, PackedPanels};
use qbound::nets::{arch, ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::store::Store;
use qbound::util;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("store", "inspect/manage the content-addressed packed-weight store")
        .positional("action", "ls | gc | warm")
        .opt("dir", "store directory (default: QBOUND_STORE_DIR)", "")
        .opt("older-than-hours", "gc: only remove files at least this old", "0")
        .flag("dry-run", "gc: report what would be removed without removing anything")
        .opt("net", "warm: network to pre-pack (default: every indexed net)", "")
        .opt(
            "weights",
            "warm: comma-separated uniform weight formats to pre-pack",
            "1.8,2.7,1.6,3.4",
        );
    let a = spec.parse(args)?;
    let dir = match a.str("dir") {
        "" => std::env::var("QBOUND_STORE_DIR")
            .ok()
            .filter(|v| !v.is_empty())
            .context("no store directory: pass --dir or set QBOUND_STORE_DIR")?,
        d => d.to_string(),
    };
    let store = Store::open(Path::new(&dir))?;
    match a.positional(0).unwrap_or("ls") {
        "ls" => ls(&store),
        "gc" => gc(&store, &a),
        "warm" => warm(&store, &a),
        other => bail!("unknown store action {other:?} (expected ls | gc | warm)"),
    }
}

fn ls(store: &Store) -> Result<()> {
    let entries = store.ls()?;
    println!("store {} — {} file(s)", store.dir().display(), entries.len());
    let mut total = 0u64;
    let mut invalid = 0usize;
    for e in &entries {
        total += e.file_bytes;
        if !e.valid {
            invalid += 1;
        }
        println!(
            "  {:<56} {:>10}  {:>8}  {}",
            e.key,
            util::human_bytes(e.file_bytes as f64),
            format_age(e.age_secs),
            if e.valid { e.desc.clone() } else { format!("INVALID ({})", e.desc) }
        );
    }
    println!("  total {} ({invalid} invalid)", util::human_bytes(total as f64));
    Ok(())
}

fn format_age(secs: u64) -> String {
    match secs {
        s if s < 120 => format!("{s}s"),
        s if s < 7200 => format!("{}m", s / 60),
        s if s < 48 * 3600 => format!("{}h", s / 3600),
        s => format!("{}d", s / 86400),
    }
}

fn gc(store: &Store, a: &Args) -> Result<()> {
    let min_age = Duration::from_secs_f64(a.f64("older-than-hours")? * 3600.0);
    let dry = a.flag("dry-run");
    let report = store.gc(min_age, dry)?;
    println!(
        "store gc {}{}: removed {} file(s) ({}), {} stale temp file(s); \
         kept {} live, {} young",
        store.dir().display(),
        if dry { " [dry run]" } else { "" },
        report.removed,
        util::human_bytes(report.removed_bytes as f64),
        report.removed_tmp,
        report.kept_live,
        report.kept_young,
    );
    Ok(())
}

/// Pre-pack the weight tensors of the selected nets at each uniform
/// weight format — exactly the (tensor, layout, format) keys the fast
/// packed executors resolve, via the same store API, so a warmed store
/// serves every later load from disk.
fn warm(store: &Store, a: &Args) -> Result<()> {
    let dir = util::artifacts_dir()?;
    let nets: Vec<String> = match a.str("net") {
        "" => ArtifactIndex::load(&dir)?.nets,
        n => vec![n.to_string()],
    };
    let fmts = a
        .list("weights")
        .iter()
        .map(|s| QFormat::parse(s))
        .collect::<Result<Vec<_>>>()
        .context("parsing --weights")?;
    anyhow::ensure!(!fmts.is_empty(), "--weights lists no formats");

    let before = store.stats();
    let mut tensors = 0usize;
    for net in &nets {
        if arch::get(net).is_none() {
            println!("  {net}: no registered architecture, skipping");
            continue;
        }
        let manifest = NetManifest::load(&dir, net)?;
        let loaded = lowering::load_network(&manifest, Variant::Standard)?;
        let plan = LoweredPlan::new(&loaded.arch, None)?;
        let mut gemm_shape: Vec<Option<(usize, usize)>> = vec![None; loaded.params.len()];
        for t in lowering::gemm_tensors(&plan.steps) {
            gemm_shape[t.param] = Some((t.kd, t.n));
        }
        for fmt in &fmts {
            let wq = vec![*fmt; manifest.n_layers()];
            let per_tensor = plan.per_tensor_formats(&wq);
            for (i, p) in loaded.params.iter().enumerate() {
                match gemm_shape[i] {
                    Some((kd, n)) => {
                        let _ = store.panels_for(p, per_tensor[i], kd, n, NR, || {
                            PackedPanels::pack(per_tensor[i], &pack_b_panels(p, kd, n), kd, NR)
                        });
                    }
                    None => {
                        let _ = store
                            .buf_for(p, per_tensor[i], || PackedBuf::pack(per_tensor[i], p));
                    }
                }
                tensors += 1;
            }
        }
        println!("  {net}: {} tensors x {} formats", loaded.params.len(), fmts.len());
    }
    let after = store.stats();
    println!(
        "store warm {}: {} tensor-format keys resolved — {} packed+published, \
         {} already present",
        store.dir().display(),
        tensors,
        after.packs - before.packs,
        (after.hits_disk - before.hits_disk) + (after.hits_shared - before.hits_shared),
    );
    Ok(())
}
