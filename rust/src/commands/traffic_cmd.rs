//! `qbound traffic` — the Fig-4 traffic model from the manifests.

use anyhow::Result;
use qbound::cli::CmdSpec;
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::report::Table;
use qbound::traffic::{self, Mode};
use qbound::util;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("traffic", "memory-traffic model (Fig 4)")
        .opt("net", "network name, or 'all'", "all")
        .opt("batch", "batch size for the batched use case", "64");
    let a = spec.parse(args)?;
    let dir = util::artifacts_dir()?;
    let batch = a.usize("batch")?;
    let nets: Vec<String> = if a.str("net") == "all" {
        ArtifactIndex::load(&dir)?.nets
    } else {
        vec![a.str("net").to_string()]
    };
    for net in nets {
        let m = NetManifest::load(&dir, &net)?;
        let single = traffic::accesses_per_image(&m, Mode::Single);
        let batched = traffic::accesses_per_image(&m, Mode::Batch(batch));
        let mut t = Table::new(
            &format!("{net} — accesses per image"),
            &["layer", "weights single", "weights batch", "data", "weight share (single)"],
        );
        for (s, b) in single.iter().zip(&batched) {
            let share = s.weight_accesses / (s.weight_accesses + s.data_accesses);
            t.row(vec![
                s.name.clone(),
                util::human_count(s.weight_accesses),
                util::human_count(b.weight_accesses),
                util::human_count(s.data_accesses),
                format!("{:.0}%", share * 100.0),
            ]);
        }
        print!("{}", t.text());
        println!(
            "total/image: single {}  batch {}\n",
            util::human_count(traffic::total_accesses(&m, Mode::Single)),
            util::human_count(traffic::total_accesses(&m, Mode::Batch(batch))),
        );
    }
    Ok(())
}
