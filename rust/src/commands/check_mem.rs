//! `qbound check-mem` — the CI memory-regression gate.
//!
//! Reads the `MEM_*.json` records the bench-smoke job archives (one per
//! net, written by `qbound eval --mem-json` under `--storage packed`)
//! and exits non-zero when any net's **measured** peak RSS exceeds its
//! **modeled** `FootprintModel::fused_envelope` by more than the
//! allowed slack. The envelope is the whole-model residency bound
//! (packed weights + peak activation bitstreams + panel padding + f32
//! scratch windows); the slack covers everything a process carries that
//! the model does not price — binary, libc, artifacts, the eval split.
//!
//! Scope, honestly stated: peak-RSS granularity is megabytes, so this
//! gate catches *process-level* regressions (a leak, an accidental
//! whole-split f32 materialization, a runaway scratch pool). The
//! fine-grained residency claim — arenas gone, weights at packed width
//! — is enforced at allocator granularity by
//! `tests/integration_memory.rs` in the tier-1 suite; this gate is the
//! per-commit backstop over the archived records. It refuses to pass
//! vacuously: no records, no measurable records, or records that were
//! not produced under packed storage are failures, not skips.

use anyhow::{bail, Result};
use qbound::cli::CmdSpec;
use qbound::util::{self, json::Json};

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("check-mem", "memory-regression gate over archived MEM_*.json")
        .opt("dir", "directory holding the MEM_*.json records", "bench-out")
        .opt("slack-mb", "allowed MiB of overhead above the modeled envelope", "64");
    let a = spec.parse(args)?;
    let slack = a.f64("slack-mb")? * 1024.0 * 1024.0;
    anyhow::ensure!(slack >= 0.0, "--slack-mb must be non-negative");

    let dir = std::path::PathBuf::from(a.str("dir"));
    let mut files: Vec<std::path::PathBuf> = std::fs::read_dir(&dir)?
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .is_some_and(|n| n.starts_with("MEM_") && n.ends_with(".json"))
        })
        .collect();
    files.sort();
    if files.is_empty() {
        // The gate must not pass vacuously: a missing record set means
        // the packed eval suite did not run.
        bail!("no MEM_*.json records under {}", dir.display());
    }

    let mut failures: Vec<String> = Vec::new();
    let mut checked = 0usize;
    for path in &files {
        let j = Json::parse(&util::read_to_string(path)?)
            .map_err(|e| anyhow::anyhow!("{}: {e:?}", path.display()))?;
        let net = j.at(&["net"]).as_str().unwrap_or("?").to_string();
        // The bound only holds for packed-storage runs; an f32 or PJRT
        // record here means the suite ran in the wrong mode.
        let storage = j.at(&["storage"]).as_str().unwrap_or("?");
        if storage != "packed" {
            failures.push(format!(
                "{net}: record {} is from a {storage:?} run, not packed storage",
                path.display()
            ));
            continue;
        }
        let Some(envelope) = j.at(&["fused_envelope_bytes"]).as_f64() else {
            failures.push(format!(
                "{net}: record {} has no fused_envelope_bytes (stale schema?)",
                path.display()
            ));
            continue;
        };
        let Some(peak) = j.at(&["peak_rss_bytes"]).as_f64() else {
            // Peak RSS is a linux procfs reading; a null means the
            // platform cannot measure, not that memory regressed.
            println!("{net:<12} no measured peak RSS — skipped");
            continue;
        };
        // A process-lifetime watermark includes the fp32 baseline eval
        // that runs before the packed target — gating it would compare
        // the wrong number (spurious failures or silently absorbed
        // regressions). eval.rs records the scope precisely so this is
        // detectable.
        let scope = j.at(&["peak_rss_scope"]).as_str().unwrap_or("?");
        if scope != "target-eval" {
            failures.push(format!(
                "{net}: peak-RSS watermark scope is {scope:?}, not \"target-eval\" \
                 (reset_peak_rss failed on this runner?)"
            ));
            continue;
        }
        checked += 1;
        let over = peak - envelope;
        let ok = over <= slack;
        println!(
            "{net:<12} peak {:>10}  envelope {:>10}  overhead {:>10}  {}",
            util::human_bytes(peak),
            util::human_bytes(envelope),
            util::human_bytes(over.max(0.0)),
            if ok { "ok" } else { "FAIL" },
        );
        if !ok {
            failures.push(format!(
                "{net}: measured peak {} exceeds envelope {} by more than the {} slack",
                util::human_bytes(peak),
                util::human_bytes(envelope),
                util::human_bytes(slack),
            ));
        }
    }
    if !failures.is_empty() {
        bail!("memory regression:\n  {}", failures.join("\n  "));
    }
    if checked == 0 {
        // Every record skipped (no measurable peak) is as vacuous as an
        // empty directory — fail so CI surfaces the broken measurement.
        bail!("no record carried a measured peak RSS; the gate checked nothing");
    }
    println!(
        "check-mem: {checked} net(s) inside the envelope (+{} slack)",
        util::human_bytes(slack)
    );
    Ok(())
}
