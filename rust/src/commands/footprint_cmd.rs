//! `qbound footprint` — the paper's headline table: per network, the
//! fp32 data footprint vs the best searched config's footprint at an
//! error tolerance, as text and (optionally) JSON.
//!
//! "Data footprint" is weights + peak live activations in bytes
//! ([`FootprintModel`], paper §3/Table-2 semantics), priced at the
//! storage widths `--storage packed` actually realizes. The best config
//! per net comes from the same §2.5 greedy search `qbound search` runs;
//! the tolerance row is the minimum-footprint visited config within
//! `--tol` relative error.

use anyhow::Result;
use qbound::backend::BackendKind;
use qbound::cli::CmdSpec;
use qbound::memory::FootprintModel;
use qbound::nets::ArtifactIndex;
use qbound::report::{pct, ratio, Table};
use qbound::repro::{self, ReproCtx};
use qbound::search::table2;
use qbound::util::{self, json::Json};

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("footprint", "fp32 vs best-config data footprint per network")
        .opt("net", "network name, or 'all'", "all")
        .opt("tol", "relative-error tolerance for the best config", "0.01")
        .opt("n-images", "images per evaluation (0 = full)", "256")
        .opt("workers", "worker threads (0 = one per core)", "0")
        .opt("out-dir", "report directory for footprint.{md,csv}", "reports")
        .opt("json", "also write the table as JSON to this path", "")
        .opt(
            "backend",
            "execution backend: reference | fast | pjrt (default: env or reference)",
            "",
        )
        .opt(
            "cache-dir",
            "descent-trajectory cache directory; \"none\" disables caching",
            "reports/dse-cache",
        );
    let a = spec.parse(args)?;

    let tol = a.f64("tol")?;
    anyhow::ensure!(tol > 0.0 && tol < 1.0, "--tol must be in (0, 1)");
    let mut ctx = ReproCtx::with_backend(
        std::path::Path::new(a.str("out-dir")),
        a.usize("workers")?,
        a.usize("n-images")?,
        BackendKind::from_arg_or_env(a.str("backend"))?,
    )?;
    let nets: Vec<String> = if a.str("net") == "all" {
        ArtifactIndex::load(&ctx.artifacts)?.nets
    } else {
        vec![a.str("net").to_string()]
    };

    let mut t = Table::new(
        &format!("Data footprint — fp32 vs best config @{:.0}% tolerance", tol * 100.0),
        &[
            "net", "fp32 bytes", "best bytes", "reduction", "weights", "peak acts", "FP", "top-1",
            "rel err",
        ],
    );
    let mut entries: Vec<Json> = Vec::new();
    // The greedy descent dominates this command's cost; repeat
    // invocations re-rank the persisted trajectory instead (any key
    // change — net, backend, n-images, artifact set — recomputes).
    let cache_dir = a.str("cache-dir").to_string();
    for net in &nets {
        let m = ctx.manifest(net)?.clone();
        let fpm = FootprintModel::new(&m);
        let base = fpm.fp32();
        let dse = if cache_dir == "none" {
            repro::explore_net(&mut ctx, net)?
        } else {
            repro::explore_net_cached(&mut ctx, net, std::path::Path::new(&cache_dir))?
        };
        let row = table2::select(&dse.descent.visited, &[tol])
            .pop()
            .flatten()
            .ok_or_else(|| anyhow::anyhow!("{net}: no config within {tol} tolerance"))?;
        let best = fpm.footprint(&row.cfg);
        let reduction = 1.0 - best.total_bytes / base.total_bytes;
        t.row(vec![
            net.clone(),
            util::human_bytes(base.total_bytes),
            util::human_bytes(best.total_bytes),
            pct(reduction),
            util::human_bytes(best.weight_bytes),
            util::human_bytes(best.peak_act_bytes),
            ratio(row.footprint_ratio),
            pct(row.accuracy),
            format!("{:.3}", row.rel_err),
        ]);
        entries.push(Json::obj(vec![
            ("net", Json::str(net.clone())),
            ("fp32_bytes", Json::num(base.total_bytes)),
            ("best_bytes", Json::num(best.total_bytes)),
            ("reduction", Json::num(reduction)),
            ("weight_bytes", Json::num(best.weight_bytes)),
            ("peak_act_bytes", Json::num(best.peak_act_bytes)),
            ("footprint_ratio", Json::num(row.footprint_ratio)),
            ("traffic_ratio", Json::num(row.traffic_ratio)),
            ("config", Json::str(row.cfg.notation())),
            ("top1", Json::num(row.accuracy)),
            ("rel_err", Json::num(row.rel_err)),
        ]));
    }
    let text = t.text();
    print!("{text}");

    let out_dir = std::path::Path::new(a.str("out-dir"));
    util::write_file(&out_dir.join("footprint.md"), t.markdown().as_bytes())?;
    util::write_file(&out_dir.join("footprint.csv"), t.csv().as_bytes())?;
    if !a.str("json").is_empty() {
        let doc = Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("tol", Json::num(tol)),
            ("n_images", Json::num(a.usize("n-images")? as f64)),
            ("nets", Json::arr(entries)),
        ]);
        let path = std::path::PathBuf::from(a.str("json"));
        util::write_file(&path, doc.pretty().as_bytes())?;
        eprintln!("footprint json -> {}", path.display());
    }
    Ok(())
}
