//! `qbound repro` — regenerate the paper's tables and figures.

use anyhow::Result;
use qbound::backend::BackendKind;
use qbound::cli::CmdSpec;
use qbound::repro::{self, ReproCtx};

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("repro", "regenerate a paper experiment")
        .positional(
            "experiment",
            "table1 | fig1 | fig2 | fig3 | fig4 | fig5 | table2 | all | ablation",
        )
        .opt("net", "network for `ablation` policy study", "convnet")
        .opt("out-dir", "report directory", "reports")
        .opt("n-images", "images per evaluation (0 = full split)", "256")
        .opt("workers", "worker threads (0 = one per core)", "0")
        .opt(
            "backend",
            "execution backend: reference | fast | pjrt (default: env or reference)",
            "",
        );
    let a = spec.parse(args)?;
    let exp = a.positional(0).unwrap_or("all").to_string();
    let mut ctx = ReproCtx::with_backend(
        std::path::Path::new(a.str("out-dir")),
        a.usize("workers")?,
        a.usize("n-images")?,
        BackendKind::from_arg_or_env(a.str("backend"))?,
    )?;
    let t0 = std::time::Instant::now();
    match exp.as_str() {
        "table1" => repro::table1(&mut ctx).map(|_| ())?,
        "fig1" => repro::fig1(&mut ctx).map(|_| ())?,
        "fig2" => repro::fig2(&mut ctx).map(|_| ())?,
        "fig3" => repro::fig3(&mut ctx).map(|_| ())?,
        "fig4" => repro::fig4(&mut ctx).map(|_| ())?,
        // fig5 and table2 come from the same exploration run
        "fig5" | "table2" => repro::fig5_table2(&mut ctx).map(|_| ())?,
        "ablation" => {
            repro::ablation_eval_subset(&mut ctx)?;
            repro::ablation_policy(&mut ctx, a.str("net"))?;
        }
        "all" => repro::all(&mut ctx).map(|_| ())?,
        other => anyhow::bail!("unknown experiment {other:?}"),
    }
    let stats = ctx.coord.stats();
    eprintln!(
        "[repro {exp}] {:.1}s — {} jobs ({} cache hits, {} executed, {} workers)",
        t0.elapsed().as_secs_f64(),
        stats.submitted,
        stats.cache_hits,
        stats.executed,
        ctx.coord.n_workers
    );
    Ok(())
}
