//! `qbound profile` — per-layer time/decode/footprint breakdown.
//!
//! Runs N single-image inferences per storage mode (packed, then f32)
//! with the [`qbound::obs`] registry enabled, then joins the per-layer
//! histograms and decode counters against the
//! [`FootprintModel`] prediction: one row per precision layer with
//! measured µs/image under both storage modes, measured packed bytes
//! decoded per image, and the modeled weight/activation bytes. Images
//! run sequentially at batch 1 so the decode-byte deltas attribute
//! exactly to the step that decoded them.

use anyhow::Result;
use qbound::backend::lowering::LoweredPlan;
use qbound::backend::{kernels, BackendKind, Variant};
use qbound::cli::CmdSpec;
use qbound::eval::Dataset;
use qbound::memory::{FootprintModel, StorageMode};
use qbound::nets::{arch, ArtifactIndex, NetManifest};
use qbound::obs;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::util;
use qbound::util::json::Json;

/// One profiled precision layer: measured times/bytes + model columns.
struct LayerRow {
    name: String,
    kind: &'static str,
    us_packed: f64,
    us_f32: f64,
    decode_bytes: f64,
    model_weight_bytes: f64,
    model_in_bytes: f64,
    model_out_bytes: f64,
}

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("profile", "per-layer time/decode/footprint breakdown")
        .opt("net", "network name, or `all`", "lenet")
        .opt("n-images", "images profiled per storage mode", "8")
        .opt("weights", "uniform weight format I.F (or fp32)", "1.8")
        .opt("data", "uniform data format I.F (or fp32)", "10.4")
        .opt("backend", "execution backend: reference | fast", "fast")
        .opt("out-dir", "directory for --json / --trace artifacts", "bench-out")
        .flag("json", "write PROFILE_<net>.json to --out-dir")
        .flag("trace", "write Chrome trace JSON (TRACE_profile.json) to --out-dir");
    let a = spec.parse(args)?;

    let dir = util::artifacts_dir()?;
    let nets: Vec<String> = if a.str("net") == "all" {
        ArtifactIndex::load(&dir)?.nets
    } else {
        vec![a.str("net").to_string()]
    };
    let n_images = a.usize("n-images")?.max(1);
    let wfmt = QFormat::parse(a.str("weights"))?;
    let dfmt = QFormat::parse(a.str("data"))?;
    let backend = BackendKind::from_arg_or_env(a.str("backend"))?;
    #[cfg(feature = "pjrt")]
    if matches!(backend, BackendKind::Pjrt) {
        anyhow::bail!("profile needs a CPU executor (reference | fast)");
    }
    let out_dir = std::path::PathBuf::from(a.str("out-dir"));

    obs::set_metrics(true);
    if a.flag("trace") {
        obs::set_tracing(true);
    }
    kernels::init()?;

    for net in &nets {
        let doc = profile_net(&dir, net, backend, wfmt, dfmt, n_images)?;
        if a.flag("json") {
            let path = out_dir.join(format!("PROFILE_{net}.json"));
            util::write_file(&path, doc.pretty().as_bytes())?;
            eprintln!("profile json -> {}", path.display());
        }
    }

    if a.flag("trace") {
        obs::set_tracing(false);
        let path = out_dir.join("TRACE_profile.json");
        obs::write_chrome_trace(&path, &obs::drain())?;
        eprintln!("trace -> {}", path.display());
    }
    Ok(())
}

/// Profile one net under both storage modes; prints the table and
/// returns the JSON document.
fn profile_net(
    dir: &std::path::Path,
    net: &str,
    backend: BackendKind,
    wfmt: QFormat,
    dfmt: QFormat,
    n_images: usize,
) -> Result<Json> {
    let m = NetManifest::load(dir, net)?;
    let a = arch::get(net)
        .ok_or_else(|| anyhow::anyhow!("no architecture registered for {net:?}"))?;
    let plan = LoweredPlan::new(&a, None)?;
    let fpm = FootprintModel::new(&m);
    let dataset = Dataset::load(&m)?;
    let nl = m.n_layers();
    let cfg = PrecisionConfig::uniform(nl, wfmt, dfmt);
    let n = n_images.min(dataset.n);

    for storage in [StorageMode::Packed, StorageMode::F32] {
        storage.set_env();
        let b = backend.create()?;
        let mut exec = b.load(&m, Variant::Standard)?;
        let (wq, dq) = (cfg.wire_wq(), cfg.wire_dq());
        for i in 0..n {
            let img = &dataset.images[i * dataset.image_elems..(i + 1) * dataset.image_elems];
            exec.infer(img, &wq, &dq, None)?;
        }
    }

    // Join measurements against the model, per precision layer.
    let model = fpm.per_layer(&cfg);
    let kinds = group_kinds(&plan, nl);
    let per_img = |sum: u64| sum as f64 / n as f64;
    let mut rows = Vec::with_capacity(nl);
    for (l, lf) in model.iter().enumerate() {
        let ls = l.to_string();
        let read_us = |storage: &'static str| {
            let labels = [("net", net), ("layer", ls.as_str()), ("storage", storage)];
            let h = obs::histogram("qbound_layer_us", "", &labels).0.snapshot();
            per_img(h.sum())
        };
        let labels = [("net", net), ("layer", ls.as_str()), ("storage", "packed")];
        let decode = obs::counter("qbound_layer_decode_bytes_total", "", &labels).get();
        rows.push(LayerRow {
            name: lf.name.clone(),
            kind: kinds[l],
            us_packed: read_us("packed"),
            us_f32: read_us("f32"),
            decode_bytes: per_img(decode),
            model_weight_bytes: lf.weight_bytes,
            model_in_bytes: lf.in_bytes,
            model_out_bytes: lf.out_bytes,
        });
    }

    let fp = fpm.footprint(&cfg);
    let envelope = fpm.fused_envelope(&cfg, plan.fused_window_elems(1), &plan.weight_pad_elems);
    let packed_weight_bytes = plan.packed_weight_bytes(&cfg.wq);
    print_table(net, &cfg, backend, n, &rows, &fp_summary(fp.weight_bytes, envelope));

    let layer_rows: Vec<Json> = rows
        .iter()
        .enumerate()
        .map(|(l, r)| {
            Json::obj(vec![
                ("layer", Json::num(l as f64)),
                ("name", Json::str(r.name.clone())),
                ("kind", Json::str(r.kind)),
                ("us_per_image_packed", Json::num(r.us_packed)),
                ("us_per_image_f32", Json::num(r.us_f32)),
                ("decode_bytes_per_image", Json::num(r.decode_bytes)),
                ("model_weight_bytes", Json::num(r.model_weight_bytes)),
                ("model_in_bytes", Json::num(r.model_in_bytes)),
                ("model_out_bytes", Json::num(r.model_out_bytes)),
            ])
        })
        .collect();
    Ok(Json::obj(vec![
        ("schema", Json::num(1.0)),
        ("net", Json::str(net)),
        ("config", Json::str(cfg.notation())),
        ("backend", Json::str(backend.label())),
        ("kernel", Json::str(kernels::active_kind().label())),
        ("n_images", Json::num(n as f64)),
        ("layers", Json::arr(layer_rows)),
        // The per-layer model columns sum to these whole-model figures
        // (same FootprintModel — `tests/integration_obs.rs` pins it).
        ("model_weight_bytes", Json::num(fp.weight_bytes)),
        ("model_total_bytes", Json::num(fp.total_bytes)),
        ("fused_envelope_bytes", Json::num(envelope)),
        ("packed_weight_bytes", Json::num(packed_weight_bytes as f64)),
    ]))
}

fn fp_summary(weight_bytes: f64, envelope: f64) -> String {
    format!(
        "model weights {}, fused envelope {}",
        util::human_bytes(weight_bytes),
        util::human_bytes(envelope)
    )
}

/// The representative op kind of each precision group: the parameterized
/// stage if the group has one (conv/dense/inception), else its first op.
fn group_kinds(plan: &LoweredPlan, nl: usize) -> Vec<&'static str> {
    let mut kinds: Vec<Option<&'static str>> = vec![None; nl];
    for step in &plan.steps {
        let slot = &mut kinds[step.group];
        // A group holds at most one parameterized op; it wins over
        // whichever shape/activation op happened to come first.
        if slot.is_none() || step.op.param_count() > 0 {
            *slot = Some(step.op.kind());
        }
    }
    kinds.into_iter().map(|k| k.unwrap_or("?")).collect()
}

fn print_table(
    net: &str,
    cfg: &PrecisionConfig,
    backend: BackendKind,
    n: usize,
    rows: &[LayerRow],
    summary: &str,
) {
    println!(
        "profile: {net} ({cfg}) backend={} kernel={} images={n}",
        backend.label(),
        kernels::active_kind().label()
    );
    println!(
        "  {:<10} {:<9} {:>12} {:>12} {:>7} {:>14} {:>12} {:>12}",
        "layer", "kind", "us/img pk", "us/img f32", "ratio", "decode B/img", "w bytes", "act in/out"
    );
    let (mut t_pk, mut t_f32, mut t_dec, mut t_w) = (0f64, 0f64, 0f64, 0f64);
    for r in rows {
        let ratio = if r.us_packed > 0.0 { r.us_f32 / r.us_packed } else { 0.0 };
        println!(
            "  {:<10} {:<9} {:>12.1} {:>12.1} {:>7.2} {:>14.0} {:>12.0} {:>6.0}/{:<6.0}",
            r.name,
            r.kind,
            r.us_packed,
            r.us_f32,
            ratio,
            r.decode_bytes,
            r.model_weight_bytes,
            r.model_in_bytes,
            r.model_out_bytes,
        );
        t_pk += r.us_packed;
        t_f32 += r.us_f32;
        t_dec += r.decode_bytes;
        t_w += r.model_weight_bytes;
    }
    println!(
        "  {:<10} {:<9} {:>12.1} {:>12.1} {:>7} {:>14.0} {:>12.0}",
        "total", "", t_pk, t_f32, "", t_dec, t_w
    );
    println!("  {summary}");
}
