//! `qbound search` — the §2.5 greedy descent for one network.

use anyhow::Result;
use qbound::backend::BackendKind;
use qbound::cli::CmdSpec;
use qbound::memory::StorageMode;
use qbound::report::{pct, ratio, Table};
use qbound::repro::{self, ReproCtx};
use qbound::search::table2;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("search", "greedy precision search (paper §2.5)")
        .opt("net", "network name", "lenet")
        .opt("n-images", "images per evaluation (0 = full)", "256")
        .opt("workers", "worker threads (0 = one per core)", "0")
        .opt("out-dir", "report directory", "reports")
        .opt(
            "backend",
            "execution backend: reference | fast | pjrt (default: env or reference)",
            "",
        )
        .opt(
            "storage",
            "inter-layer activation storage: f32 | packed (default: env or f32)",
            "",
        );
    let a = spec.parse(args)?;
    // Workers build backends from the environment; propagate --storage.
    StorageMode::from_arg_or_env(a.str("storage"))?.set_env();
    let mut ctx = ReproCtx::with_backend(
        std::path::Path::new(a.str("out-dir")),
        a.usize("workers")?,
        a.usize("n-images")?,
        BackendKind::from_arg_or_env(a.str("backend"))?,
    )?;
    let net = a.str("net").to_string();
    let dse = repro::explore_net(&mut ctx, &net)?;

    println!(
        "descent: {} steps, {} configs explored, baseline {:.4}",
        dse.descent.visited.len(),
        dse.descent.explored.len(),
        dse.descent.baseline
    );
    let mut t = Table::new(
        &format!("{net} — minimum footprint per tolerance"),
        &["tol", "data bits", "weight F", "top-1", "rel err", "FP", "TR"],
    );
    for row in dse.rows.iter().flatten() {
        let data = if repro::data_f_policy(&net).is_some() {
            table2::notation_total(&row.cfg)
        } else {
            table2::notation_if(&row.cfg)
        };
        t.row(vec![
            format!("{:.0}%", row.tol * 100.0),
            data,
            table2::notation_weights(&row.cfg),
            pct(row.accuracy),
            format!("{:.3}", row.rel_err),
            ratio(row.footprint_ratio),
            ratio(row.traffic_ratio),
        ]);
    }
    print!("{}", t.text());
    Ok(())
}
