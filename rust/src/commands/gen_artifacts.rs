//! `qbound gen-artifacts` — synthesize a pure-Rust artifact set.
//!
//! Produces everything the reference backend, the search stack, the
//! benches and the integration tests need — manifests, He-initialized
//! weights, teacher-labelled eval splits, golden quantization vectors —
//! without the python/JAX build path. See [`qbound::artifacts`].

use anyhow::Result;
use qbound::artifacts::{self, GenOptions};
use qbound::cli::CmdSpec;
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::util;

pub fn run(args: &[String]) -> Result<()> {
    let spec = CmdSpec::new("gen-artifacts", "synthesize a pure-Rust artifact set")
        .opt("out", "output directory", "artifacts")
        .opt("seed", "generator seed (hex or decimal; empty = built-in)", "")
        .opt("n-eval", "eval images per network", "256");
    let a = spec.parse(args)?;

    let mut opts = GenOptions::default();
    let seed = a.str("seed");
    if !seed.is_empty() {
        opts.seed = match seed.strip_prefix("0x") {
            Some(hex) => u64::from_str_radix(hex, 16)
                .map_err(|e| anyhow::anyhow!("--seed: {e}"))?,
            None => seed.parse().map_err(|e| anyhow::anyhow!("--seed: {e}"))?,
        };
    }
    opts.n_eval = a.usize("n-eval")?;
    anyhow::ensure!(opts.n_eval >= opts.batch, "--n-eval must be at least {}", opts.batch);

    let dir = std::path::PathBuf::from(a.str("out"));
    let t0 = std::time::Instant::now();
    artifacts::generate(&dir, &opts)?;

    // Summarize what was written (also proves the manifests re-parse).
    let index = ArtifactIndex::load(&dir)?;
    println!(
        "artifacts: {} ({} nets, batch={}, n_eval={}, {:.1}s)",
        dir.display(),
        index.nets.len(),
        index.batch,
        opts.n_eval,
        t0.elapsed().as_secs_f64()
    );
    for net in &index.nets {
        let m = NetManifest::load(&dir, net)?;
        println!(
            "  {:<10} {} layers  {:>8} weights  {:>8} MACs/img",
            m.name,
            m.n_layers(),
            util::human_count(m.total_weights() as f64),
            util::human_count(m.total_macs() as f64),
        );
    }
    Ok(())
}
