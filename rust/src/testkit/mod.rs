//! Property-testing harness (substrate — `proptest` is unavailable offline).
//!
//! A small QuickCheck-style runner: generators draw from [`Xoshiro256pp`],
//! failures are minimized by a bounded shrink loop, and every failure
//! report includes the seed so runs reproduce exactly.
//!
//! ```ignore
//! forall(cases(512), gen_f32(-100.0, 100.0), |&x| {
//!     let q = quantize(x, fmt);
//!     prop(q <= fmt.hi(), "saturates above")
//! });
//! ```

use crate::prng::Xoshiro256pp;

/// Locate — or synthesize — an artifact set for tests and benches.
///
/// Resolution order:
///   1. whatever [`crate::util::artifacts_dir`] already finds
///      (`QBOUND_ARTIFACTS`, an `artifacts/` dir up the tree, or a
///      previously-populated cache);
///   2. otherwise synthesize into the per-user cache
///      ([`crate::artifacts::default_cache_dir`]) — which
///      `artifacts_dir()` also resolves, so no environment mutation is
///      needed (mutating env vars mid-process races concurrent getenv).
///
/// Synthesis runs at most once per process; concurrent processes race
/// benignly on an atomic rename.
pub fn ensure_artifacts() -> std::path::PathBuf {
    use std::sync::OnceLock;
    static DIR: OnceLock<std::path::PathBuf> = OnceLock::new();
    DIR.get_or_init(|| {
        if let Ok(d) = crate::util::artifacts_dir() {
            return d;
        }
        let opts = crate::artifacts::GenOptions::default();
        let dest = crate::artifacts::default_cache_dir();
        if !dest.join("index.json").exists() {
            let tmp = dest.with_extension(format!("tmp-{}", std::process::id()));
            let _ = std::fs::remove_dir_all(&tmp);
            crate::artifacts::generate(&tmp, &opts).expect("synthesizing test artifacts");
            if let Err(e) = std::fs::rename(&tmp, &dest) {
                // Lost a race with another process: fine if the winner
                // completed; otherwise surface the error.
                if !dest.join("index.json").exists() {
                    panic!("installing artifacts at {}: {e}", dest.display());
                }
                let _ = std::fs::remove_dir_all(&tmp);
            }
        }
        dest
    })
    .clone()
}

/// Reference semantics for the packed-storage contract, shared by the
/// `memory::packed` unit tests and `tests/property_packed.rs`:
/// [`QFormat::quantize_slice`](crate::quant::QFormat::quantize_slice)
/// output with `-0.0` canonicalized to `+0.0` (`+ 0.0` maps `-0.0` to
/// `+0.0` and is the identity elsewhere — two's complement has a
/// single zero).
pub fn quantized_canonical(fmt: crate::quant::QFormat, xs: &[f32]) -> Vec<f32> {
    let mut v = xs.to_vec();
    fmt.quantize_slice(&mut v);
    for x in &mut v {
        *x += 0.0;
    }
    v
}

// ---- allocation metering -----------------------------------------------------

/// A counting [`GlobalAlloc`](std::alloc::GlobalAlloc) wrapper around
/// the system allocator: tracks live and peak heap bytes so tests can
/// *measure* the memory bound instead of modeling it. Install it as the
/// `#[global_allocator]` of a test binary (see
/// `tests/integration_memory.rs`); production binaries never register
/// it, so it costs nothing outside the memory tests.
///
/// Counters are process-global — tests that read them must serialize
/// (the memory test binary guards every test with one mutex) and should
/// assert with slack for harness noise.
pub struct MeterAlloc;

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicUsize, Ordering::Relaxed};

static METER_LIVE: AtomicUsize = AtomicUsize::new(0);
static METER_PEAK: AtomicUsize = AtomicUsize::new(0);

fn meter_record(n: usize) {
    let live = METER_LIVE.fetch_add(n, Relaxed) + n;
    METER_PEAK.fetch_max(live, Relaxed);
}

unsafe impl GlobalAlloc for MeterAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc(layout);
        if !p.is_null() {
            meter_record(layout.size());
        }
        p
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        let p = System.alloc_zeroed(layout);
        if !p.is_null() {
            meter_record(layout.size());
        }
        p
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout);
        METER_LIVE.fetch_sub(layout.size(), Relaxed);
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        let p = System.realloc(ptr, layout, new_size);
        if !p.is_null() {
            if new_size >= layout.size() {
                meter_record(new_size - layout.size());
            } else {
                METER_LIVE.fetch_sub(layout.size() - new_size, Relaxed);
            }
        }
        p
    }
}

impl MeterAlloc {
    /// Currently allocated heap bytes.
    pub fn live_bytes() -> usize {
        METER_LIVE.load(Relaxed)
    }

    /// High-water heap bytes since the last [`MeterAlloc::reset_peak`].
    pub fn peak_bytes() -> usize {
        METER_PEAK.load(Relaxed)
    }

    /// Restart peak tracking from the current live level.
    pub fn reset_peak() {
        METER_PEAK.store(METER_LIVE.load(Relaxed), Relaxed);
    }
}

/// Configuration for a property run.
#[derive(Clone, Copy, Debug)]
pub struct Config {
    pub cases: usize,
    pub seed: u64,
    pub max_shrinks: usize,
}

impl Default for Config {
    fn default() -> Self {
        Self { cases: 256, seed: Q_SEED, max_shrinks: 512 }
    }
}

/// Default property-test seed (override per-run via [`Config::seed`]).
const Q_SEED: u64 = 0x51b0_07e5_7a11_0c1d;

/// Shorthand: default config with `n` cases.
pub fn cases(n: usize) -> Config {
    Config { cases: n, ..Config::default() }
}

/// A value generator: produces a case and can propose shrunk variants.
pub trait Gen {
    type Value: std::fmt::Debug + Clone;
    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value;
    /// Candidate "smaller" values, tried in order during shrinking.
    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let _ = v;
        Vec::new()
    }
}

/// Outcome of a single property check.
#[derive(Clone, Debug)]
pub enum Outcome {
    Pass,
    Fail(String),
}

/// Assert helper: `prop(cond, "message")`.
pub fn prop(cond: bool, msg: &str) -> Outcome {
    if cond {
        Outcome::Pass
    } else {
        Outcome::Fail(msg.to_string())
    }
}

/// Combine outcomes: first failure wins.
pub fn all(outcomes: impl IntoIterator<Item = Outcome>) -> Outcome {
    for o in outcomes {
        if let Outcome::Fail(_) = o {
            return o;
        }
    }
    Outcome::Pass
}

/// Run `check` against `cfg.cases` generated values; panic (with seed and
/// shrunk counterexample) on failure. Returns the number of passed cases.
pub fn forall<G: Gen>(cfg: Config, gen: G, check: impl Fn(&G::Value) -> Outcome) -> usize {
    let mut rng = Xoshiro256pp::new(cfg.seed);
    for case in 0..cfg.cases {
        let v = gen.generate(&mut rng);
        if let Outcome::Fail(msg) = check(&v) {
            // shrink
            let mut best = v.clone();
            let mut best_msg = msg;
            let mut budget = cfg.max_shrinks;
            'outer: loop {
                for cand in gen.shrink(&best) {
                    if budget == 0 {
                        break 'outer;
                    }
                    budget -= 1;
                    if let Outcome::Fail(m) = check(&cand) {
                        best = cand;
                        best_msg = m;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (case {case}, seed {:#x}):\n  value: {:?}\n  reason: {}",
                cfg.seed, best, best_msg
            );
        }
    }
    cfg.cases
}

// ---- stock generators --------------------------------------------------------

/// Uniform f32 in [lo, hi) plus occasional special values.
pub struct GenF32 {
    pub lo: f32,
    pub hi: f32,
}

pub fn gen_f32(lo: f32, hi: f32) -> GenF32 {
    GenF32 { lo, hi }
}

impl Gen for GenF32 {
    type Value = f32;

    fn generate(&self, rng: &mut Xoshiro256pp) -> f32 {
        // 1-in-16 cases draw from a pool of boundary-ish values.
        if rng.below(16) == 0 {
            let pool = [0.0f32, -0.0, 0.5, -0.5, 1.0, -1.0, 0.25, 1.5, -2.5, self.lo, self.hi];
            pool[rng.below(pool.len() as u64) as usize]
        } else {
            rng.uniform_f32(self.lo, self.hi)
        }
    }

    fn shrink(&self, v: &f32) -> Vec<f32> {
        let mut out = Vec::new();
        if *v != 0.0 {
            out.push(0.0);
            out.push(v / 2.0);
            out.push(v.trunc());
        }
        out.retain(|c| c != v);
        out
    }
}

/// Uniform i64 in [lo, hi].
pub struct GenI64 {
    pub lo: i64,
    pub hi: i64,
}

pub fn gen_i64(lo: i64, hi: i64) -> GenI64 {
    GenI64 { lo, hi }
}

impl Gen for GenI64 {
    type Value = i64;

    fn generate(&self, rng: &mut Xoshiro256pp) -> i64 {
        rng.range_i64(self.lo, self.hi)
    }

    fn shrink(&self, v: &i64) -> Vec<i64> {
        let mut out = Vec::new();
        if *v != 0 && self.lo <= 0 && self.hi >= 0 {
            out.push(0);
        }
        out.push(v / 2);
        out.retain(|c| c != v && *c >= self.lo && *c <= self.hi);
        out
    }
}

/// Pair of independent generators.
pub struct GenPair<A, B>(pub A, pub B);

impl<A: Gen, B: Gen> Gen for GenPair<A, B> {
    type Value = (A::Value, B::Value);

    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        (self.0.generate(rng), self.1.generate(rng))
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out: Vec<Self::Value> =
            self.0.shrink(&v.0).into_iter().map(|a| (a, v.1.clone())).collect();
        out.extend(self.1.shrink(&v.1).into_iter().map(|b| (v.0.clone(), b)));
        out
    }
}

/// Vec of values from an element generator, length in [min_len, max_len].
pub struct GenVec<G> {
    pub elem: G,
    pub min_len: usize,
    pub max_len: usize,
}

pub fn gen_vec<G: Gen>(elem: G, min_len: usize, max_len: usize) -> GenVec<G> {
    GenVec { elem, min_len, max_len }
}

impl<G: Gen> Gen for GenVec<G> {
    type Value = Vec<G::Value>;

    fn generate(&self, rng: &mut Xoshiro256pp) -> Self::Value {
        let len = self.min_len + rng.below((self.max_len - self.min_len + 1) as u64) as usize;
        (0..len).map(|_| self.elem.generate(rng)).collect()
    }

    fn shrink(&self, v: &Self::Value) -> Vec<Self::Value> {
        let mut out = Vec::new();
        if v.len() > self.min_len {
            out.push(v[..v.len() / 2.max(self.min_len)].to_vec());
            let mut tail = v.clone();
            tail.remove(0);
            out.push(tail);
        }
        out.retain(|c| c.len() >= self.min_len);
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let n = forall(cases(128), gen_f32(-10.0, 10.0), |&x| prop(x.abs() <= 10.0, "bound"));
        assert_eq!(n, 128);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics_with_seed() {
        forall(cases(64), gen_i64(0, 100), |&x| prop(x < 90, "x must stay below 90"));
    }

    #[test]
    fn shrinking_reaches_small_counterexample() {
        // Capture panic message and assert the counterexample shrank to <= 52.
        let result = std::panic::catch_unwind(|| {
            forall(cases(64), gen_i64(0, 1000), |&x| prop(x < 50, "ge 50"));
        });
        let msg = match result {
            Err(e) => e.downcast::<String>().map(|b| *b).unwrap_or_default(),
            Ok(_) => panic!("should have failed"),
        };
        // shrinker halves toward 0; smallest failing value is 50..=99 range
        let val: i64 = msg
            .split("value: ")
            .nth(1)
            .and_then(|s| s.split_whitespace().next())
            .and_then(|s| s.parse().ok())
            .expect("parse counterexample");
        assert!((50..100).contains(&val), "shrunk value {val}");
    }

    #[test]
    fn pair_and_vec_generators_compose() {
        forall(
            cases(64),
            GenPair(gen_i64(1, 8), gen_vec(gen_f32(-1.0, 1.0), 0, 16)),
            |(n, v)| all([prop(*n >= 1, "n"), prop(v.len() <= 16, "len")]),
        );
    }

    #[test]
    fn deterministic_given_seed() {
        use std::cell::RefCell;
        let seen_a = RefCell::new(Vec::new());
        forall(Config { cases: 16, seed: 7, max_shrinks: 0 }, gen_i64(0, 1000), |&x| {
            seen_a.borrow_mut().push(x);
            Outcome::Pass
        });
        let seen_b = RefCell::new(Vec::new());
        forall(Config { cases: 16, seed: 7, max_shrinks: 0 }, gen_i64(0, 1000), |&x| {
            seen_b.borrow_mut().push(x);
            Outcome::Pass
        });
        assert_eq!(seen_a.into_inner(), seen_b.into_inner());
    }
}
