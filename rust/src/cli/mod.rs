//! Declarative CLI argument parser (substrate — `clap` is unavailable offline).
//!
//! Supports subcommands, `--flag`, `--key value` / `--key=value`, typed
//! accessors with defaults, required arguments, and generated `--help`.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

/// Specification of one option.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<&'static str>,
    pub is_flag: bool,
    pub required: bool,
}

/// Specification of a (sub)command: its options and positional params.
#[derive(Clone, Debug, Default)]
pub struct CmdSpec {
    pub name: &'static str,
    pub about: &'static str,
    pub opts: Vec<OptSpec>,
    pub positionals: Vec<(&'static str, &'static str)>, // (name, help)
}

impl CmdSpec {
    pub fn new(name: &'static str, about: &'static str) -> Self {
        Self { name, about, ..Default::default() }
    }

    pub fn opt(mut self, name: &'static str, help: &'static str, default: &'static str) -> Self {
        let default = Some(default);
        self.opts.push(OptSpec { name, help, default, is_flag: false, required: false });
        self
    }

    pub fn opt_req(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: false, required: true });
        self
    }

    pub fn flag(mut self, name: &'static str, help: &'static str) -> Self {
        self.opts.push(OptSpec { name, help, default: None, is_flag: true, required: false });
        self
    }

    pub fn positional(mut self, name: &'static str, help: &'static str) -> Self {
        self.positionals.push((name, help));
        self
    }

    /// Render the help text.
    pub fn help(&self, prog: &str) -> String {
        let mut s = format!("{} — {}\n\nUSAGE:\n  {prog} {}", self.name, self.about, self.name);
        for (p, _) in &self.positionals {
            s.push_str(&format!(" <{p}>"));
        }
        s.push_str(" [OPTIONS]\n");
        if !self.positionals.is_empty() {
            s.push_str("\nARGS:\n");
            for (p, h) in &self.positionals {
                s.push_str(&format!("  <{p:<12}>  {h}\n"));
            }
        }
        if !self.opts.is_empty() {
            s.push_str("\nOPTIONS:\n");
            for o in &self.opts {
                let mut line = format!("  --{:<18} {}", o.name, o.help);
                if let Some(d) = o.default {
                    line.push_str(&format!(" [default: {d}]"));
                }
                if o.required {
                    line.push_str(" [required]");
                }
                s.push_str(&line);
                s.push('\n');
            }
        }
        s
    }

    /// Parse `args` (everything after the subcommand name).
    pub fn parse(&self, args: &[String]) -> Result<Args> {
        let mut values: BTreeMap<String, String> = BTreeMap::new();
        let mut flags: Vec<String> = Vec::new();
        let mut positionals: Vec<String> = Vec::new();
        let mut it = args.iter().peekable();
        while let Some(a) = it.next() {
            if a == "--help" || a == "-h" {
                bail!("{}", self.help("qbound"));
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (key, inline_val) = match stripped.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self.opts.iter().find(|o| o.name == key).ok_or_else(|| {
                    anyhow::anyhow!("unknown option --{key}\n\n{}", self.help("qbound"))
                })?;
                if spec.is_flag {
                    if inline_val.is_some() {
                        bail!("--{key} is a flag and takes no value");
                    }
                    flags.push(key);
                } else {
                    let val = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| anyhow::anyhow!("--{key} needs a value"))?,
                    };
                    values.insert(key, val);
                }
            } else {
                positionals.push(a.clone());
            }
        }
        if positionals.len() > self.positionals.len() {
            bail!(
                "unexpected positional {:?}\n\n{}",
                positionals[self.positionals.len()],
                self.help("qbound")
            );
        }
        for o in &self.opts {
            if o.required && !values.contains_key(o.name) {
                bail!("missing required option --{}\n\n{}", o.name, self.help("qbound"));
            }
            if let Some(d) = o.default {
                values.entry(o.name.to_string()).or_insert_with(|| d.to_string());
            }
        }
        Ok(Args { values, flags, positionals })
    }
}

/// Parsed arguments with typed accessors.
#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    flags: Vec<String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> &str {
        self.get(name).unwrap_or_else(|| panic!("option --{name} has no value/default"))
    }

    pub fn usize(&self, name: &str) -> Result<usize> {
        self.str(name).parse().map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn f64(&self, name: &str) -> Result<f64> {
        self.str(name).parse().map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn i32(&self, name: &str) -> Result<i32> {
        self.str(name).parse().map_err(|e| anyhow::anyhow!("--{name}: {e}"))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn positional(&self, idx: usize) -> Option<&str> {
        self.positionals.get(idx).map(|s| s.as_str())
    }

    /// Comma-separated list option.
    pub fn list(&self, name: &str) -> Vec<String> {
        self.str(name)
            .split(',')
            .map(|s| s.trim().to_string())
            .filter(|s| !s.is_empty())
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> CmdSpec {
        CmdSpec::new("eval", "run an evaluation")
            .opt("net", "network name", "lenet")
            .opt("batches", "number of batches", "16")
            .opt_req("config", "precision config")
            .flag("verbose", "chatty output")
            .positional("target", "what to evaluate")
    }

    fn s(v: &[&str]) -> Vec<String> {
        v.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_required() {
        let a = spec().parse(&s(&["--config", "1.8"])).unwrap();
        assert_eq!(a.str("net"), "lenet");
        assert_eq!(a.usize("batches").unwrap(), 16);
        assert_eq!(a.str("config"), "1.8");
        assert!(!a.flag("verbose"));
    }

    #[test]
    fn missing_required_errors() {
        assert!(spec().parse(&s(&[])).is_err());
    }

    #[test]
    fn equals_and_space_forms() {
        let a = spec().parse(&s(&["--config=2.4", "--batches", "8", "--verbose"])).unwrap();
        assert_eq!(a.str("config"), "2.4");
        assert_eq!(a.usize("batches").unwrap(), 8);
        assert!(a.flag("verbose"));
    }

    #[test]
    fn positionals_captured_and_excess_rejected() {
        let a = spec().parse(&s(&["--config", "x", "thing"])).unwrap();
        assert_eq!(a.positional(0), Some("thing"));
        assert!(spec().parse(&s(&["--config", "x", "a", "b"])).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        assert!(spec().parse(&s(&["--nope", "1"])).is_err());
    }

    #[test]
    fn flag_with_value_rejected() {
        assert!(spec().parse(&s(&["--config", "x", "--verbose=1"])).is_err());
    }

    #[test]
    fn list_parsing() {
        let sp = CmdSpec::new("x", "y").opt("nets", "nets", "a,b, c");
        let a = sp.parse(&s(&[])).unwrap();
        assert_eq!(a.list("nets"), vec!["a", "b", "c"]);
    }

    #[test]
    fn help_contains_options() {
        let h = spec().help("qbound");
        assert!(h.contains("--net"));
        assert!(h.contains("[default: lenet]"));
        assert!(h.contains("[required]"));
    }
}
