//! Per-layer tolerance characterization (the paper's Fig-3 experiment) for
//! one network, showing how precision tolerance varies *within* a network.
//!
//! ```sh
//! cargo run --release --example per_layer_sweep [net]
//! ```

use anyhow::Result;
use qbound::coordinator::Coordinator;
use qbound::nets::NetManifest;
use qbound::report::{Chart, Table};
use qbound::search::{perlayer, uniform, Param};
use qbound::util;

fn main() -> Result<()> {
    util::init_logging();
    let net = std::env::args().nth(1).unwrap_or_else(|| "convnet".into());
    let dir = qbound::testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, &net)?;
    let mut coord = Coordinator::new(&dir, 0)?;
    let n_images = 256;

    println!("sweeping {} ({} layers) one layer at a time…", m.name, m.n_layers());
    let params = [Param::WeightF, Param::DataI, Param::DataF];
    let ranges = [(1i8, 10i8), (1, 12), (0, 6)];
    let mut summary = Table::new(
        &format!("{net} — per-layer minimum bits (within 1% of baseline)"),
        &["layer", "kind", "weight F", "data I", "data F"],
    );
    let mut mins = Vec::new();
    for (pi, &param) in params.iter().enumerate() {
        let matrix = perlayer::sweep_all_layers(
            &mut coord,
            &net,
            m.n_layers(),
            &[param],
            ranges[pi],
            n_images,
        )?;
        // chart one param fully: data integer bits
        if param == Param::DataI {
            let mut chart =
                Chart::new(&format!("{net} — data integer bits, one layer at a time"),
                    "data integer bits", "relative accuracy");
            let markers = ['1', '2', '3', '4', '5', '6', '7', '8', '9', 'a', 'b', 'c'];
            for (l, series) in matrix[0].iter().enumerate() {
                chart.series(
                    markers[l % markers.len()],
                    series.iter().map(|p| (p.bits as f64, p.relative)).collect(),
                );
            }
            print!("{}", chart.render());
        }
        mins.push(perlayer::min_bits_per_layer(&matrix[0], 0.01));
    }
    for l in 0..m.n_layers() {
        summary.row(vec![
            m.layers[l].name.clone(),
            m.layers[l].kind.clone(),
            mins[0][l].map(|b| b.to_string()).unwrap_or("-".into()),
            mins[1][l].map(|b| b.to_string()).unwrap_or("-".into()),
            mins[2][l].map(|b| b.to_string()).unwrap_or("-".into()),
        ]);
    }
    print!("{}", summary.text());

    // The paper's key observation: variance WITHIN the network.
    let di: Vec<i8> = mins[1].iter().flatten().copied().collect();
    if let (Some(&lo), Some(&hi)) = (di.iter().min(), di.iter().max()) {
        println!(
            "\ndata-integer tolerance varies {lo}..{hi} bits across layers — \
             {} bits of per-layer headroom vs the uniform worst case",
            hi - lo
        );
    }
    // Contrast with the uniform requirement (Fig 2 style).
    let upts = uniform::sweep(&mut coord, &net, m.n_layers(), Param::DataI, (1, 12), n_images)?;
    if let Some(u) = uniform::min_bits_within(&upts, 0.01) {
        println!("uniform data-integer requirement: {u} bits (the network-wide worst case)");
    }
    Ok(())
}
