//! Quickstart: load a compiled network, evaluate it at fp32 and at a
//! reduced-precision configuration, and report accuracy + traffic.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```
//! (synthesizes artifacts on first run; `make artifacts` swaps in the
//! python-built set)

use anyhow::Result;
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::nets::NetManifest;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::traffic::{self, Mode};
use qbound::util;

fn main() -> Result<()> {
    util::init_logging();
    let dir = qbound::testkit::ensure_artifacts();
    let net = "lenet";
    let m = NetManifest::load(&dir, net)?;
    println!(
        "{}: {} layers, {} weights, trained baseline top-1 {:.4}",
        m.name,
        m.n_layers(),
        util::human_count(m.total_weights() as f64),
        m.baseline_top1
    );

    // One worker is plenty for a single network.
    let mut coord = Coordinator::new(&dir, 1)?;

    // fp32 baseline through the PJRT runtime (should match the manifest).
    let fp32 = PrecisionConfig::fp32(m.n_layers());
    let base = coord.eval_one(EvalJob { net: net.into(), cfg: fp32, n_images: 0 })?;
    println!("fp32 baseline (rust runtime): {base:.4}");

    // A reduced-precision configuration: 1.8 weights, 10.2 data (12 bits).
    let cfg = PrecisionConfig::uniform(
        m.n_layers(),
        QFormat::parse("1.8")?,
        QFormat::parse("10.2")?,
    );
    let acc = coord.eval_one(EvalJob { net: net.into(), cfg: cfg.clone(), n_images: 0 })?;
    let tr = traffic::traffic_ratio(&m, Mode::Batch(m.batch), &cfg);
    println!(
        "quantized {}: top-1 {acc:.4} (rel err {:.3}), traffic ratio {tr:.3} ({:.0}% less traffic)",
        cfg,
        (base - acc) / base,
        (1.0 - tr) * 100.0
    );

    // Per-layer mixed precision: squeeze late layers harder.
    let mut mixed = cfg.clone();
    for l in 0..m.n_layers() {
        if l >= m.n_layers() / 2 {
            mixed.dq[l] = QFormat::new(6, 1);
            mixed.wq[l] = QFormat::new(1, 5);
        }
    }
    let acc_m = coord.eval_one(EvalJob { net: net.into(), cfg: mixed.clone(), n_images: 0 })?;
    let tr_m = traffic::traffic_ratio(&m, Mode::Batch(m.batch), &mixed);
    println!(
        "mixed {}: top-1 {acc_m:.4} (rel err {:.3}), traffic ratio {tr_m:.3}",
        mixed,
        (base - acc_m) / base
    );
    println!("\n(cache: {} entries, {:?})", coord.cache_len(), coord.stats());
    Ok(())
}
