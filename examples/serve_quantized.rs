//! End-to-end driver: deploy a searched mixed-precision network behind the
//! coordinator and serve a Poisson stream of classification requests,
//! reporting latency, throughput, accuracy and the effective traffic
//! ratio — the "bounded-memory deployment" the paper motivates.
//!
//! All layers compose here: L1 Pallas quantize kernels inside the L2
//! JAX-lowered HLO, executed by the L3 coordinator's PJRT workers.
//!
//! ```sh
//! cargo run --release --example serve_quantized [net] [requests] [rate]
//! ```

use std::time::Duration;

use anyhow::Result;
use qbound::coordinator::{Coordinator, EvalJob};
use qbound::nets::NetManifest;
use qbound::prng::Xoshiro256pp;
use qbound::quant::QFormat;
use qbound::search::space::PrecisionConfig;
use qbound::traffic::{self, Mode};
use qbound::util;

fn main() -> Result<()> {
    util::init_logging();
    let net = std::env::args().nth(1).unwrap_or_else(|| "convnet".into());
    let n_req: usize = std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(48);
    let rate: f64 = std::env::args().nth(3).and_then(|s| s.parse().ok()).unwrap_or(6.0);

    let dir = qbound::testkit::ensure_artifacts();
    let m = NetManifest::load(&dir, &net)?;
    let nl = m.n_layers();

    // A production-ish mixed config: early layers wider, late layers narrow
    // (the shape the paper's search converges to).
    let mut cfg = PrecisionConfig::uniform(nl, QFormat::new(1, 8), QFormat::new(10, 2));
    for l in 0..nl {
        if l * 2 >= nl {
            cfg.dq[l] = QFormat::new(8, 1);
            cfg.wq[l] = QFormat::new(1, 6);
        }
    }
    let tr = traffic::traffic_ratio(&m, Mode::Batch(m.batch), &cfg);

    let workers = qbound::coordinator::default_workers();
    let mut coord = Coordinator::new(&dir, workers)?;
    let n_images = m.batch; // one batch per request

    // Warm both workers (compile once, off the clock).
    println!("warming {workers} workers on {net}…");
    coord.eval_batch(&vec![
        EvalJob { net: net.clone(), cfg: PrecisionConfig::fp32(nl), n_images };
        workers
    ])?;
    let base = coord.eval_one(EvalJob {
        net: net.clone(),
        cfg: PrecisionConfig::fp32(nl),
        n_images: 0,
    })?;
    let acc = coord.eval_one(EvalJob { net: net.clone(), cfg: cfg.clone(), n_images: 0 })?;

    // Poisson arrivals; per-request UNIQUE config (rotating fields span a
    // space ≫ n_req) defeats the memo cache so every request pays real
    // inference.
    let mut rng = Xoshiro256pp::new(7);
    let mut arrivals = Vec::with_capacity(n_req);
    let mut t = 0.0;
    for i in 0..n_req {
        t += rng.exponential(rate);
        let mut c = cfg.clone();
        c.dq[i % nl].fbits = 2 + ((i / nl) % 12) as i8;
        c.dq[(i + 1) % nl].ibits = 8 + ((i / (nl * 12)) % 6) as i8;
        arrivals.push((Duration::from_secs_f64(t), EvalJob { net: net.clone(), cfg: c, n_images }));
    }

    let t0 = std::time::Instant::now();
    let lat = coord.run_stream(&arrivals)?;
    let wall = t0.elapsed();
    let mut sorted = lat.clone();
    sorted.sort_unstable();
    let p = |q: f64| sorted[((sorted.len() - 1) as f64 * q) as usize];

    println!(
        "\nserve_quantized — {net}, {n_req} requests, Poisson rate {rate}/s, {workers} workers"
    );
    println!("  config          {cfg}");
    println!("  accuracy        {acc:.4}  (fp32 {base:.4}, rel err {:.3})", (base - acc) / base);
    println!("  traffic ratio   {tr:.3} vs fp32  ({:.0}% reduction)", (1.0 - tr) * 100.0);
    println!("  wall            {}", util::human_duration(wall));
    println!(
        "  throughput      {:.1} req/s = {:.0} img/s",
        n_req as f64 / wall.as_secs_f64(),
        (n_req * n_images) as f64 / wall.as_secs_f64()
    );
    println!(
        "  latency         p50 {}  p95 {}  p99 {}  max {}",
        util::human_duration(p(0.50)),
        util::human_duration(p(0.95)),
        util::human_duration(p(0.99)),
        util::human_duration(*sorted.last().unwrap())
    );
    let busy = coord.busy_time().as_secs_f64();
    println!(
        "  utilization     {:.0}% across {workers} workers",
        100.0 * busy / (wall.as_secs_f64() * workers as f64)
    );
    Ok(())
}
