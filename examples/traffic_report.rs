//! The paper's Fig-4 traffic analysis across all shipped networks:
//! single-image vs batched classification, weights vs data, and where the
//! bytes actually go.
//!
//! ```sh
//! cargo run --release --example traffic_report
//! ```

use anyhow::Result;
use qbound::nets::{ArtifactIndex, NetManifest};
use qbound::quant::QFormat;
use qbound::report::Table;
use qbound::search::space::PrecisionConfig;
use qbound::traffic::{self, Mode};
use qbound::util;

fn main() -> Result<()> {
    util::init_logging();
    let dir = qbound::testkit::ensure_artifacts();
    let index = ArtifactIndex::load(&dir)?;

    let mut t = Table::new(
        "traffic per image (accesses; batch amortizes weights)",
        &[
            "net", "weights", "data", "single total", "batch total", "weights share single",
            "weights share batch",
        ],
    );
    for name in &index.nets {
        let m = NetManifest::load(&dir, name)?;
        let single = traffic::accesses_per_image(&m, Mode::Single);
        let batch = traffic::accesses_per_image(&m, Mode::Batch(m.batch));
        let w: f64 = single.iter().map(|l| l.weight_accesses).sum();
        let d: f64 = single.iter().map(|l| l.data_accesses).sum();
        let wb: f64 = batch.iter().map(|l| l.weight_accesses).sum();
        t.row(vec![
            name.clone(),
            util::human_count(w),
            util::human_count(d),
            util::human_count(w + d),
            util::human_count(wb + d),
            format!("{:.0}%", 100.0 * w / (w + d)),
            format!("{:.0}%", 100.0 * wb / (wb + d)),
        ]);
    }
    print!("{}", t.text());

    // What a 16-bit uniform and an aggressive mixed config buy, per net.
    let mut t2 = Table::new(
        "bit-weighted traffic ratio vs fp32 (batch mode)",
        &["net", "uniform 16-bit", "uniform 8-bit", "half-net mixed 8/16"],
    );
    for name in &index.nets {
        let m = NetManifest::load(&dir, name)?;
        let nl = m.n_layers();
        let u16 = PrecisionConfig::uniform(nl, QFormat::new(1, 15), QFormat::new(14, 2));
        let u8c = PrecisionConfig::uniform(nl, QFormat::new(1, 7), QFormat::new(6, 2));
        let mut mixed = u16.clone();
        for l in nl / 2..nl {
            mixed.dq[l] = QFormat::new(6, 2);
            mixed.wq[l] = QFormat::new(1, 7);
        }
        let mode = Mode::Batch(m.batch);
        t2.row(vec![
            name.clone(),
            format!("{:.3}", traffic::traffic_ratio(&m, mode, &u16)),
            format!("{:.3}", traffic::traffic_ratio(&m, mode, &u8c)),
            format!("{:.3}", traffic::traffic_ratio(&m, mode, &mixed)),
        ]);
    }
    print!("{}", t2.text());
    println!("\nNote: accuracy impact of these configs is measured by `qbound eval` /");
    println!("the fig5 exploration; this example isolates the traffic model itself.");
    Ok(())
}
