//! Run the paper's §2.5 greedy precision search for one network and print
//! its accuracy/traffic Pareto frontier and Table-2-style rows.
//!
//! ```sh
//! cargo run --release --example pareto_search [net] [n_images]
//! ```

use anyhow::Result;
use qbound::report::{pct, ratio, Chart, Table};
use qbound::repro::{self, ReproCtx};
use qbound::search::{pareto, table2};

fn main() -> Result<()> {
    qbound::util::init_logging();
    qbound::testkit::ensure_artifacts();
    let net = std::env::args().nth(1).unwrap_or_else(|| "lenet".into());
    let n_images: usize =
        std::env::args().nth(2).and_then(|s| s.parse().ok()).unwrap_or(256);
    let mut ctx = ReproCtx::new(std::path::Path::new("reports"), 0, n_images)?;

    let t0 = std::time::Instant::now();
    let dse = repro::explore_net(&mut ctx, &net)?;
    println!(
        "explored {} configurations in {:.1}s (descent length {})",
        dse.descent.explored.len(),
        t0.elapsed().as_secs_f64(),
        dse.descent.visited.len()
    );

    let pts: Vec<(f64, f64)> =
        dse.descent.explored.iter().map(|v| (v.traffic_ratio, v.accuracy)).collect();
    let front = pareto::frontier(&pts);
    let mut chart = Chart::new(
        &format!("{net} — design space (accuracy vs traffic)"),
        "traffic ratio vs 32-bit",
        "top-1",
    );
    chart.series('.', pts.clone());
    chart.series('#', front.iter().map(|&i| pts[i]).collect());
    print!("{}", chart.render());

    let mut t = Table::new(
        &format!("{net} — min traffic per tolerance (Table 2 row)"),
        &["tol", "data bits/layer", "weight F/layer", "top-1", "TR"],
    );
    for row in dse.rows.iter().flatten() {
        let data = if repro::data_f_policy(&net).is_some() {
            table2::notation_total(&row.cfg)
        } else {
            table2::notation_if(&row.cfg)
        };
        t.row(vec![
            format!("{:.0}%", row.tol * 100.0),
            data,
            table2::notation_weights(&row.cfg),
            pct(row.accuracy),
            ratio(row.traffic_ratio),
        ]);
    }
    print!("{}", t.text());
    Ok(())
}
